//! Stochastic-block-model graph generator.
//!
//! The paper's datasets are unavailable offline (DESIGN.md §4); what
//! Cluster-GCN's results *depend on* is (a) clusterable topology,
//! (b) label distributions skewed within clusters, (c) features
//! correlated with labels.  An SBM with label-correlated communities
//! reproduces all three: METIS-like partitioning recovers communities
//! (high embedding utilization), random partitioning does not, and the
//! Fig. 2 entropy contrast emerges from the community→label coupling.
//!
//! Edge sampling is O(m) (expected-count per block, not O(n²) coin
//! flips), so the `amazon2m_like` preset (160k nodes, ~2M entries)
//! generates in seconds.

use crate::graph::Csr;
use crate::util::Rng;

/// Generator spec; see `presets.rs` for the paper-matched instances.
#[derive(Clone, Debug)]
pub struct SbmSpec {
    pub n: usize,
    /// number of ground-truth communities (>= 1).
    pub communities: usize,
    /// target average degree (undirected).
    pub avg_deg: f64,
    /// fraction of edges with both endpoints in the same community.
    pub intra_frac: f64,
    /// community size skew: sizes ~ (1 + skew * U[0,1)), normalized.
    pub size_skew: f64,
}

/// Generated community structure.
pub struct SbmGraph {
    pub graph: Csr,
    /// community id per node.
    pub community: Vec<u32>,
    /// nodes grouped by community.
    pub members: Vec<Vec<u32>>,
}

pub fn generate(spec: &SbmSpec, rng: &mut Rng) -> SbmGraph {
    let (community, members) = layout(spec, rng);
    let m_total = (spec.n as f64 * spec.avg_deg / 2.0) as usize;
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(m_total + m_total / 8);
    emit_edges(spec, &members, rng, |u, v| edges.push((u, v)));
    let graph = Csr::from_edges(spec.n, &edges);
    SbmGraph { graph, community, members }
}

/// Community layout: skewed sizes + shuffled node→community map.
/// Consumes exactly the size/permutation draws of [`generate`]; split
/// out so the streaming generator (`datagen::stream`) can replay the
/// same RNG stream without materializing the edge list.
pub fn layout(spec: &SbmSpec, rng: &mut Rng) -> (Vec<u32>, Vec<Vec<u32>>) {
    assert!(spec.communities >= 1 && spec.n >= spec.communities);
    let k = spec.communities;

    // --- community sizes ------------------------------------------------
    let mut raw: Vec<f64> = (0..k).map(|_| 1.0 + spec.size_skew * rng.f64()).collect();
    let total: f64 = raw.iter().sum();
    raw.iter_mut().for_each(|r| *r /= total);
    let mut sizes: Vec<usize> = raw.iter().map(|r| (r * spec.n as f64) as usize).collect();
    // fix rounding: distribute the remainder, ensure every community >= 1
    let mut assigned: usize = sizes.iter().sum();
    let mut i = 0;
    while assigned < spec.n {
        sizes[i % k] += 1;
        assigned += 1;
        i += 1;
    }
    for s in sizes.iter_mut() {
        if *s == 0 {
            *s = 1;
        }
    }
    while sizes.iter().sum::<usize>() > spec.n {
        let j = sizes.iter().position(|&s| s > 1).unwrap();
        sizes[j] -= 1;
    }

    // --- node -> community (contiguous blocks, then shuffled ids) -------
    // Node ids are shuffled so that id order carries no community signal
    // (random partition must not accidentally align with communities).
    let mut perm: Vec<u32> = (0..spec.n as u32).collect();
    rng.shuffle(&mut perm);
    let mut community = vec![0u32; spec.n];
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); k];
    let mut cursor = 0;
    for (c, &sz) in sizes.iter().enumerate() {
        for &node in &perm[cursor..cursor + sz] {
            community[node as usize] = c as u32;
            members[c].push(node);
        }
        cursor += sz;
    }
    (community, members)
}

/// Sample the edge stream into `sink`. Consumes exactly the edge draws
/// of [`generate`], in the same order; emitted pairs may repeat (and,
/// for single-community specs, include self loops) — consumers
/// deduplicate exactly like [`Csr::from_edges`].
pub fn emit_edges(
    spec: &SbmSpec,
    members: &[Vec<u32>],
    rng: &mut Rng,
    mut sink: impl FnMut(u32, u32),
) {
    let k = members.len();
    let sizes: Vec<usize> = members.iter().map(|m| m.len()).collect();
    let m_total = (spec.n as f64 * spec.avg_deg / 2.0) as usize;
    let m_intra = (m_total as f64 * spec.intra_frac) as usize;
    let m_inter = m_total - m_intra;

    // intra edges: communities weighted by size (uniform expected degree)
    let cum: Vec<f64> = {
        let mut acc = 0.0;
        sizes
            .iter()
            .map(|&s| {
                acc += s as f64;
                acc
            })
            .collect()
    };
    let pick_comm = |rng: &mut Rng| -> usize {
        let t = rng.f64() * spec.n as f64;
        match cum.binary_search_by(|x| x.partial_cmp(&t).unwrap()) {
            Ok(i) | Err(i) => i.min(k - 1),
        }
    };
    for _ in 0..m_intra {
        let c = pick_comm(rng);
        let mem = &members[c];
        if mem.len() < 2 {
            continue;
        }
        let u = mem[rng.usize_below(mem.len())];
        let v = mem[rng.usize_below(mem.len())];
        if u != v {
            sink(u, v);
        }
    }
    for _ in 0..m_inter {
        let c1 = pick_comm(rng);
        let mut c2 = pick_comm(rng);
        if k > 1 {
            while c2 == c1 {
                c2 = pick_comm(rng);
            }
        }
        let u = members[c1][rng.usize_below(members[c1].len())];
        let v = members[c2][rng.usize_below(members[c2].len())];
        sink(u, v);
    }

    // connectivity floor: chain each community's members + chain the
    // community representatives so the graph has one component (METIS
    // and BFS-based initial partitioning behave better, and real GCN
    // datasets are dominated by one giant component).
    for mem in members {
        for w in mem.windows(2) {
            if rng.f64() < 0.3 {
                sink(w[0], w[1]);
            }
        }
    }
    for w in members.windows(2) {
        sink(w[0][0], w[1][0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SbmSpec {
        SbmSpec {
            n: 2000,
            communities: 20,
            avg_deg: 10.0,
            intra_frac: 0.85,
            size_skew: 1.0,
        }
    }

    #[test]
    fn basic_shape() {
        let mut rng = Rng::new(1);
        let g = generate(&spec(), &mut rng);
        assert_eq!(g.graph.n(), 2000);
        g.graph.validate().unwrap();
        let (_, _, avg) = g.graph.degree_stats();
        // avg directed degree ~ 10 (some dedup loss tolerated)
        assert!(avg > 7.0 && avg < 13.0, "avg={avg}");
    }

    #[test]
    fn communities_cover_all_nodes() {
        let mut rng = Rng::new(2);
        let g = generate(&spec(), &mut rng);
        let total: usize = g.members.iter().map(|m| m.len()).sum();
        assert_eq!(total, 2000);
        for (c, mem) in g.members.iter().enumerate() {
            for &v in mem {
                assert_eq!(g.community[v as usize], c as u32);
            }
        }
    }

    #[test]
    fn intra_fraction_respected() {
        let mut rng = Rng::new(3);
        let g = generate(&spec(), &mut rng);
        let mut intra = 0usize;
        let mut total = 0usize;
        for v in 0..g.graph.n() {
            for &u in g.graph.neighbors(v) {
                total += 1;
                if g.community[v] == g.community[u as usize] {
                    intra += 1;
                }
            }
        }
        let frac = intra as f64 / total as f64;
        assert!(frac > 0.75, "intra frac too low: {frac}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let g1 = generate(&spec(), &mut r1);
        let g2 = generate(&spec(), &mut r2);
        assert_eq!(g1.graph.cols, g2.graph.cols);
        assert_eq!(g1.community, g2.community);
    }

    #[test]
    fn node_ids_not_aligned_with_communities() {
        // shuffled ids: the first n/k node ids must not all be in one
        // community (that would make random partition == clustering).
        let mut rng = Rng::new(9);
        let g = generate(&spec(), &mut rng);
        let first: Vec<u32> = (0..100).map(|v| g.community[v]).collect();
        let distinct: std::collections::HashSet<_> = first.iter().collect();
        assert!(distinct.len() > 5, "ids leak community structure");
    }
}
