//! Cluster-GCN training state + the pre-driver compatibility surface.
//!
//! The epoch loop that used to live here (Algorithm 1: sample q
//! clusters, assemble the renormalized union block, fused `train_step`,
//! periodic exact eval) is now the pull-based
//! [`crate::session::Driver`]: batch production is a
//! [`crate::coordinator::source::ClusterSource`], execution pulls
//! through [`Backend::step_from`] (where the sharded/prefetch
//! combinators overlap and fan out), and the loop itself is a state
//! machine the caller advances.  This module keeps what the loop
//! *produced* and what older callers still use:
//!
//! - [`TrainState`] / [`TrainResult`] / [`CurvePoint`] — the model
//!   state and run accounting types,
//! - [`train`] / [`train_observed`] / [`step`] — thin wrappers over the
//!   unified [`crate::session::TrainConfig`] that build a driver and
//!   drain it (the legacy `TrainOptions` shim served its one-release
//!   deprecation window in PR 4 and is gone),
//! - [`evaluate`] / [`evaluate_cached`] — the exact host evaluator.

use anyhow::Result;

use crate::coordinator::sampler::ClusterSampler;
use crate::coordinator::source::ClusterSource;
use crate::coordinator::inference::{full_forward_cached, gather_rows};
use crate::coordinator::metrics::micro_f1;
use crate::graph::Dataset;
use crate::norm::{NormCache, NormConfig};
use crate::runtime::{Backend, ModelSpec, PrefetchBackend, Tensor};
use crate::session::driver::{BackendSlot, Driver, DriverSource};
use crate::session::{NullObserver, Observer, TrainConfig};
use crate::util::Rng;

/// Model parameters + Adam state, fed through the backend each step.
#[derive(Clone)]
pub struct TrainState {
    pub weights: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub step: u64,
}

impl TrainState {
    /// Glorot-uniform init from a typed [`ModelSpec`] (matches
    /// `model.init_weights` in spirit; the exact stream differs —
    /// reproducibility is per-side, keyed by seed).  Backend-neutral:
    /// callers holding an `ArtifactMeta` convert via
    /// `ModelSpec::from(&meta)`.
    pub fn init(spec: &ModelSpec, seed: u64) -> TrainState {
        let mut rng = Rng::new(seed ^ 0x1717_C6CA_11AD_0001);
        let mut weights = Vec::new();
        let mut m = Vec::new();
        let mut v = Vec::new();
        for &(fi, fo) in &spec.weight_shapes {
            let bound = (6.0 / (fi + fo) as f64).sqrt() as f32;
            let data: Vec<f32> = (0..fi * fo)
                .map(|_| (rng.f32() * 2.0 - 1.0) * bound)
                .collect();
            weights.push(Tensor::new(vec![fi, fo], data));
            m.push(Tensor::zeros(vec![fi, fo]));
            v.push(Tensor::zeros(vec![fi, fo]));
        }
        TrainState { weights, m, v, step: 0 }
    }

    pub fn param_bytes(&self) -> usize {
        self.weights.iter().map(|w| w.size_bytes()).sum::<usize>() * 3
    }
}

#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub epoch: usize,
    /// cumulative *training* seconds (eval time excluded, like the
    /// paper's Fig. 6 x-axis).
    pub train_seconds: f64,
    pub train_loss: f64,
    pub eval_f1: f64,
}

pub struct TrainResult {
    pub state: TrainState,
    pub curve: Vec<CurvePoint>,
    pub train_seconds: f64,
    pub steps: u64,
    /// peak bytes of (batch tensors + param/opt state) — the measured
    /// analogue of Table 5's training memory.
    pub peak_bytes: usize,
    /// total within-batch directed edges / total batch nodes (embedding
    /// utilization diagnostics).
    pub avg_within_edges_per_node: f64,
}

/// Run Cluster-GCN training on any backend; the sampler supplies
/// cluster batches.  Thin wrapper over [`train_observed`] with no
/// observer attached.
pub fn train(
    backend: &mut dyn Backend,
    ds: &Dataset,
    sampler: &ClusterSampler,
    model: &str,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    train_observed(backend, ds, sampler, model, cfg, &mut NullObserver)
}

/// [`train`] with an [`Observer`] receiving the full [`crate::session::Event`]
/// stream.  Pre-driver compatibility entry: builds a
/// [`Driver`] over a [`ClusterSource`] and drains it; the caller's
/// backend is wrapped in a [`PrefetchBackend`] so this path keeps the
/// assembly/execute overlap the old loop had.  The config's
/// model-shape fields are inert here — the driver reads shapes from
/// the backend's [`ModelSpec`].
pub fn train_observed(
    backend: &mut dyn Backend,
    ds: &Dataset,
    sampler: &ClusterSampler,
    model: &str,
    cfg: &TrainConfig,
    obs: &mut dyn Observer,
) -> Result<TrainResult> {
    let spec = backend.model_spec(model)?;
    let cfg = cfg.clone();
    let source = ClusterSource::new(ds, sampler.clone(), &spec, cfg.norm, cfg.seed)?;
    let mut backend = PrefetchBackend::new(backend);
    let mut driver = Driver::from_parts(
        BackendSlot::Borrowed(&mut backend),
        ds,
        model.to_string(),
        cfg,
        DriverSource::Batched(Box::new(source)),
        None,
    )?;
    driver.drive(obs)?;
    driver.into_result()
}

/// One fused train step over an assembled batch; updates `state`
/// in-place and returns the batch loss.  Thin delegate to
/// [`Backend::train_step`], kept for probes and one-off callers.
pub fn step(
    backend: &mut dyn Backend,
    model: &str,
    state: &mut TrainState,
    lr: f32,
    batch: &crate::coordinator::batch::Batch,
) -> Result<f32> {
    backend.train_step(model, state, lr, batch)
}

/// Exact host-side evaluation (full-graph inference) → micro-F1.
/// One-off wrapper paying a fresh normalization; loops that evaluate
/// repeatedly must hold a [`NormCache`] and call [`evaluate_cached`].
pub fn evaluate(
    ds: &Dataset,
    weights: &[Tensor],
    norm: NormConfig,
    residual: bool,
    nodes: &[u32],
) -> f64 {
    let mut cache = NormCache::new();
    evaluate_cached(ds, weights, norm, residual, nodes, &mut cache)
}

/// [`evaluate`] against a caller-owned normalization cache: repeated
/// evaluations over one dataset never re-run `normalize_sparse`.
pub fn evaluate_cached(
    ds: &Dataset,
    weights: &[Tensor],
    norm: NormConfig,
    residual: bool,
    nodes: &[u32],
    cache: &mut NormCache,
) -> f64 {
    if nodes.is_empty() {
        return 0.0;
    }
    let logits = full_forward_cached(ds, weights, norm, residual, cache);
    let rows = gather_rows(&logits, ds.num_classes, nodes);
    micro_f1(ds, nodes, &rows, ds.num_classes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Split, Task};

    fn fake_spec() -> ModelSpec {
        ModelSpec::gcn(Task::Multiclass, 2, 8, 16, 4, 128)
    }

    #[test]
    fn init_shapes_and_range() {
        let st = TrainState::init(&fake_spec(), 3);
        assert_eq!(st.weights.len(), 2);
        assert_eq!(st.weights[0].dims, vec![8, 16]);
        assert_eq!(st.m[1].dims, vec![16, 4]);
        let bound = (6.0f64 / 24.0).sqrt() as f32;
        assert!(st.weights[0].data.iter().all(|&w| w.abs() <= bound));
        assert!(st.m.iter().all(|t| t.data.iter().all(|&x| x == 0.0)));
        // not all zero
        assert!(st.weights[0].data.iter().any(|&w| w != 0.0));
    }

    #[test]
    fn init_deterministic_per_seed() {
        let a = TrainState::init(&fake_spec(), 1);
        let b = TrainState::init(&fake_spec(), 1);
        let c = TrainState::init(&fake_spec(), 2);
        assert_eq!(a.weights[0].data, b.weights[0].data);
        assert_ne!(a.weights[0].data, c.weights[0].data);
    }

    #[test]
    fn param_bytes_counts_adam() {
        let st = TrainState::init(&fake_spec(), 0);
        let one_set = (8 * 16 + 16 * 4) * 4;
        assert_eq!(st.param_bytes(), 3 * one_set);
    }

    /// The acceptance invariant behind the NormCache: a multi-eval run
    /// normalizes the full graph exactly once per config.
    #[test]
    fn multi_eval_normalizes_once() {
        let ds = crate::datagen::build(crate::datagen::preset("cora_like").unwrap(), 7);
        let w0 = Tensor::new(
            vec![ds.f_in, 8],
            (0..ds.f_in * 8).map(|i| ((i % 23) as f32 - 11.0) * 0.01).collect(),
        );
        let w1 = Tensor::new(
            vec![8, ds.num_classes],
            (0..8 * ds.num_classes).map(|i| ((i % 17) as f32 - 8.0) * 0.02).collect(),
        );
        let weights = vec![w0, w1];
        let nodes = ds.nodes_in_split(Split::Val);
        let mut cache = NormCache::new();
        let first = evaluate_cached(
            &ds, &weights, NormConfig::PAPER_DEFAULT, false, &nodes, &mut cache,
        );
        for _ in 0..4 {
            let again = evaluate_cached(
                &ds, &weights, NormConfig::PAPER_DEFAULT, false, &nodes, &mut cache,
            );
            assert_eq!(first, again);
        }
        assert_eq!(cache.computes(), 1, "normalize_sparse must run once per config");
    }
}
