//! The Cluster-GCN training loop (Algorithm 1): sample q clusters,
//! assemble the renormalized union block, run the fused `train_step` on
//! the active [`Backend`], keep params/Adam state across steps;
//! periodically evaluate with exact host inference.
//!
//! The loop is backend-generic: the same code drives the PJRT engine
//! (AOT artifacts) and the artifact-free [`crate::runtime::HostBackend`].
//! [`crate::session::Session`] is the primary entry point; the free
//! functions here are the engine room it (and the benches) call into.
//!
//! Hot-loop engineering (PERF.md): batches double-buffer through two
//! reusable [`Batch`] buffers on a [`pipeline`] — batch `i + 1` is
//! assembled on a helper thread while the backend executes batch `i` —
//! and all full-graph evaluations share one [`NormCache`], so
//! `normalize_sparse` runs at most once per (dataset, config) per
//! training run.  Every assembled batch is sparse-native: it carries a
//! CSR `SparseBlock` view of its normalized block alongside the dense
//! tensors, which the host backend's pooled backward engine
//! (`runtime::backward`) consumes directly — the PJRT engine keeps the
//! dense view.

use anyhow::{anyhow, Result};

use crate::coordinator::batch::{Batch, BatchAssembler};
use crate::coordinator::inference::{full_forward_cached, gather_rows};
use crate::coordinator::metrics::micro_f1;
use crate::coordinator::sampler::ClusterSampler;
use crate::coordinator::schedule::{EarlyStopper, LrSchedule};
use crate::graph::{Dataset, Split};
use crate::norm::{NormCache, NormConfig};
use crate::runtime::{Backend, ModelSpec, Tensor};
use crate::session::{Event, NullObserver, Observer};
use crate::util::pool::pipeline;
use crate::util::{Rng, Timer};

/// Model parameters + Adam state, fed through the backend each step.
#[derive(Clone)]
pub struct TrainState {
    pub weights: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub step: u64,
}

impl TrainState {
    /// Glorot-uniform init from a typed [`ModelSpec`] (matches
    /// `model.init_weights` in spirit; the exact stream differs —
    /// reproducibility is per-side, keyed by seed).  Backend-neutral:
    /// callers holding an `ArtifactMeta` convert via
    /// `ModelSpec::from(&meta)`.
    pub fn init(spec: &ModelSpec, seed: u64) -> TrainState {
        let mut rng = Rng::new(seed ^ 0x1717_C6CA_11AD_0001);
        let mut weights = Vec::new();
        let mut m = Vec::new();
        let mut v = Vec::new();
        for &(fi, fo) in &spec.weight_shapes {
            let bound = (6.0 / (fi + fo) as f64).sqrt() as f32;
            let data: Vec<f32> = (0..fi * fo)
                .map(|_| (rng.f32() * 2.0 - 1.0) * bound)
                .collect();
            weights.push(Tensor::new(vec![fi, fo], data));
            m.push(Tensor::zeros(vec![fi, fo]));
            v.push(Tensor::zeros(vec![fi, fo]));
        }
        TrainState { weights, m, v, step: 0 }
    }

    pub fn param_bytes(&self) -> usize {
        self.weights.iter().map(|w| w.size_bytes()).sum::<usize>() * 3
    }
}

#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub lr: f32,
    pub epochs: usize,
    /// evaluate every k epochs (0 = only at the end).
    pub eval_every: usize,
    pub seed: u64,
    pub norm: NormConfig,
    /// evaluate on this split for the convergence curve.
    pub eval_split: Split,
    /// cap steps per epoch (0 = no cap); memory/timing benches use a
    /// few steps to reach peak state without a full pass.
    pub max_steps_per_epoch: usize,
    /// learning-rate schedule over epochs (lr is a runtime input).
    pub schedule: LrSchedule,
    /// early-stop patience in evals (0 = disabled).
    pub patience: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            lr: 0.01, // the paper: Adam, lr 0.01, for every method
            epochs: 40,
            eval_every: 5,
            seed: 0,
            norm: NormConfig::PAPER_DEFAULT,
            eval_split: Split::Val,
            max_steps_per_epoch: 0,
            schedule: LrSchedule::Constant,
            patience: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub epoch: usize,
    /// cumulative *training* seconds (eval time excluded, like the
    /// paper's Fig. 6 x-axis).
    pub train_seconds: f64,
    pub train_loss: f64,
    pub eval_f1: f64,
}

pub struct TrainResult {
    pub state: TrainState,
    pub curve: Vec<CurvePoint>,
    pub train_seconds: f64,
    pub steps: u64,
    /// peak bytes of (batch tensors + param/opt state) — the measured
    /// analogue of Table 5's training memory.
    pub peak_bytes: usize,
    /// total within-batch directed edges / total batch nodes (embedding
    /// utilization diagnostics).
    pub avg_within_edges_per_node: f64,
}

/// Run Cluster-GCN training on any backend; the sampler supplies
/// cluster batches.  Thin wrapper over [`train_observed`] with no
/// observer attached.
pub fn train(
    backend: &mut dyn Backend,
    ds: &Dataset,
    sampler: &ClusterSampler,
    model: &str,
    opts: &TrainOptions,
) -> Result<TrainResult> {
    train_observed(backend, ds, sampler, model, opts, &mut NullObserver)
}

/// [`train`] with an [`Observer`] receiving epoch/eval/early-stop
/// events as they happen.
pub fn train_observed(
    backend: &mut dyn Backend,
    ds: &Dataset,
    sampler: &ClusterSampler,
    model: &str,
    opts: &TrainOptions,
    obs: &mut dyn Observer,
) -> Result<TrainResult> {
    let spec = backend.model_spec(model)?;
    if sampler.max_batch_nodes() > spec.b_max {
        return Err(anyhow!(
            "sampler can produce {} nodes but model {} has b_max={}",
            sampler.max_batch_nodes(),
            model,
            spec.b_max
        ));
    }
    backend.prepare(model)?;

    let mut state = TrainState::init(&spec, opts.seed);
    let mut rng = Rng::new(opts.seed ^ 0x5A5A_0000_1111_2222);
    let mut assembler = BatchAssembler::new(ds.n(), spec.b_max, opts.norm);
    let eval_nodes = ds.nodes_in_split(opts.eval_split);
    let mut norm_cache = NormCache::new();

    let mut curve = Vec::new();
    let mut train_seconds = 0.0;
    let mut steps = 0u64;
    let mut peak_bytes = 0usize;
    let mut within_edges = 0u64;
    let mut batch_nodes = 0u64;
    let mut nodes_buf: Vec<u32> = Vec::new();
    // double buffer: batch i+1 assembles while the backend executes
    // batch i; the two Batch buffers live for the whole run (no
    // per-step allocs)
    let mut buf_a = assembler.new_batch(ds);
    let mut buf_b = assembler.new_batch(ds);

    let mut stopper = EarlyStopper::new(opts.patience);
    for epoch in 1..=opts.epochs {
        let lr = opts.schedule.lr_at(opts.lr, epoch, opts.epochs);
        let timer = Timer::start();
        let plan = sampler.epoch_plan(&mut rng);
        let mut epoch_loss = 0.0f64;
        let mut epoch_batches = 0usize;
        let mut step_err: Option<anyhow::Error> = None;
        {
            let assembler = &mut assembler;
            let nodes_buf = &mut nodes_buf;
            let plan = &plan;
            (buf_a, buf_b) = pipeline(
                plan.len(),
                buf_a,
                buf_b,
                |i, batch: &mut Batch| {
                    sampler.batch_nodes(&plan[i], nodes_buf);
                    assembler.assemble_into(ds, nodes_buf, batch);
                },
                |_i, batch: &mut Batch| {
                    if batch.n_train == 0 {
                        return true; // nothing to learn from (all val/test)
                    }
                    within_edges += batch.within_edges as u64;
                    batch_nodes += batch.n_real as u64;
                    peak_bytes = peak_bytes.max(batch.bytes() + state.param_bytes());
                    match backend.train_step(model, &mut state, lr, batch) {
                        Ok(loss) => {
                            epoch_loss += loss as f64;
                            epoch_batches += 1;
                            steps += 1;
                        }
                        Err(e) => {
                            step_err = Some(e);
                            return false;
                        }
                    }
                    // stop after the cap; the in-flight prefetch is the
                    // only wasted work
                    !(opts.max_steps_per_epoch > 0
                        && epoch_batches >= opts.max_steps_per_epoch)
                },
            );
        }
        if let Some(e) = step_err {
            return Err(e);
        }
        train_seconds += timer.secs();
        obs.on_event(&Event::EpochEnd {
            epoch,
            train_seconds,
            mean_loss: epoch_loss / epoch_batches.max(1) as f64,
        });

        let do_eval = (opts.eval_every > 0 && epoch % opts.eval_every == 0)
            || epoch == opts.epochs;
        if do_eval {
            let f1 = evaluate_cached(
                ds,
                &state.weights,
                opts.norm,
                spec.residual,
                &eval_nodes,
                &mut norm_cache,
            );
            curve.push(CurvePoint {
                epoch,
                train_seconds,
                train_loss: epoch_loss / epoch_batches.max(1) as f64,
                eval_f1: f1,
            });
            obs.on_event(&Event::Eval { point: curve.last().unwrap() });
            if stopper.update(f1) {
                obs.on_event(&Event::EarlyStop { epoch, best: stopper.best() });
                break; // early stop: no improvement for `patience` evals
            }
        }
    }

    Ok(TrainResult {
        state,
        curve,
        train_seconds,
        steps,
        peak_bytes,
        avg_within_edges_per_node: within_edges as f64 / batch_nodes.max(1) as f64,
    })
}

/// One fused train step over an assembled batch; updates `state`
/// in-place and returns the batch loss.  Thin delegate to
/// [`Backend::train_step`], kept for probes and one-off callers.
pub fn step(
    backend: &mut dyn Backend,
    model: &str,
    state: &mut TrainState,
    lr: f32,
    batch: &crate::coordinator::batch::Batch,
) -> Result<f32> {
    backend.train_step(model, state, lr, batch)
}

/// Exact host-side evaluation (full-graph inference) → micro-F1.
/// One-off wrapper paying a fresh normalization; loops that evaluate
/// repeatedly must hold a [`NormCache`] and call [`evaluate_cached`].
pub fn evaluate(
    ds: &Dataset,
    weights: &[Tensor],
    norm: NormConfig,
    residual: bool,
    nodes: &[u32],
) -> f64 {
    let mut cache = NormCache::new();
    evaluate_cached(ds, weights, norm, residual, nodes, &mut cache)
}

/// [`evaluate`] against a caller-owned normalization cache: repeated
/// evaluations over one dataset never re-run `normalize_sparse`.
pub fn evaluate_cached(
    ds: &Dataset,
    weights: &[Tensor],
    norm: NormConfig,
    residual: bool,
    nodes: &[u32],
    cache: &mut NormCache,
) -> f64 {
    if nodes.is_empty() {
        return 0.0;
    }
    let logits = full_forward_cached(ds, weights, norm, residual, cache);
    let rows = gather_rows(&logits, ds.num_classes, nodes);
    micro_f1(ds, nodes, &rows, ds.num_classes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Task;

    fn fake_spec() -> ModelSpec {
        ModelSpec::gcn(Task::Multiclass, 2, 8, 16, 4, 128)
    }

    #[test]
    fn init_shapes_and_range() {
        let st = TrainState::init(&fake_spec(), 3);
        assert_eq!(st.weights.len(), 2);
        assert_eq!(st.weights[0].dims, vec![8, 16]);
        assert_eq!(st.m[1].dims, vec![16, 4]);
        let bound = (6.0f64 / 24.0).sqrt() as f32;
        assert!(st.weights[0].data.iter().all(|&w| w.abs() <= bound));
        assert!(st.m.iter().all(|t| t.data.iter().all(|&x| x == 0.0)));
        // not all zero
        assert!(st.weights[0].data.iter().any(|&w| w != 0.0));
    }

    #[test]
    fn init_deterministic_per_seed() {
        let a = TrainState::init(&fake_spec(), 1);
        let b = TrainState::init(&fake_spec(), 1);
        let c = TrainState::init(&fake_spec(), 2);
        assert_eq!(a.weights[0].data, b.weights[0].data);
        assert_ne!(a.weights[0].data, c.weights[0].data);
    }

    #[test]
    fn param_bytes_counts_adam() {
        let st = TrainState::init(&fake_spec(), 0);
        let one_set = (8 * 16 + 16 * 4) * 4;
        assert_eq!(st.param_bytes(), 3 * one_set);
    }

    /// The acceptance invariant behind the NormCache: a multi-eval run
    /// normalizes the full graph exactly once per config.
    #[test]
    fn multi_eval_normalizes_once() {
        let ds = crate::datagen::build(crate::datagen::preset("cora_like").unwrap(), 7);
        let w0 = Tensor::new(
            vec![ds.f_in, 8],
            (0..ds.f_in * 8).map(|i| ((i % 23) as f32 - 11.0) * 0.01).collect(),
        );
        let w1 = Tensor::new(
            vec![8, ds.num_classes],
            (0..8 * ds.num_classes).map(|i| ((i % 17) as f32 - 8.0) * 0.02).collect(),
        );
        let weights = vec![w0, w1];
        let nodes = ds.nodes_in_split(Split::Val);
        let mut cache = NormCache::new();
        let first = evaluate_cached(
            &ds, &weights, NormConfig::PAPER_DEFAULT, false, &nodes, &mut cache,
        );
        for _ in 0..4 {
            let again = evaluate_cached(
                &ds, &weights, NormConfig::PAPER_DEFAULT, false, &nodes, &mut cache,
            );
            assert_eq!(first, again);
        }
        assert_eq!(cache.computes(), 1, "normalize_sparse must run once per config");
    }
}
