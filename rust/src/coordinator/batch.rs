//! Batch assembly: node union → induced adjacency block (with the
//! between-cluster links added back, §3.2) → per-batch renormalization
//! (§6.2) → padded dense tensors for the AOT executable.
//!
//! This is the L3 hot path: all buffers live in a reusable
//! `BatchAssembler` and are overwritten per batch (DESIGN.md §8).

use crate::graph::{Dataset, Split, SubgraphScratch};
use crate::norm::{build_dense_block, NormConfig};
use crate::runtime::Tensor;

/// Assembled batch, ready to feed the train/eval executable.
pub struct Batch {
    /// global node ids (local index = position).
    pub nodes: Vec<u32>,
    /// (b_max, b_max) normalized adjacency block.
    pub a: Tensor,
    /// (b_max, f_in) features.
    pub x: Tensor,
    /// (b_max, classes) one-/multi-hot labels.
    pub y: Tensor,
    /// (b_max,) loss mask (1.0 = labeled training node).
    pub mask: Tensor,
    /// number of real (non-padding) nodes.
    pub n_real: usize,
    /// directed edges inside the batch (embedding utilization, §3.1).
    pub within_edges: usize,
    /// labeled nodes in the batch.
    pub n_train: usize,
}

pub struct BatchAssembler {
    pub b_max: usize,
    pub norm: NormConfig,
    scratch: SubgraphScratch,
    edges: Vec<(u32, u32)>,
}

impl BatchAssembler {
    pub fn new(n_graph: usize, b_max: usize, norm: NormConfig) -> Self {
        BatchAssembler {
            b_max,
            norm,
            scratch: SubgraphScratch::new(n_graph),
            edges: Vec::new(),
        }
    }

    /// Assemble a batch over `nodes` using the graph's induced edges.
    pub fn assemble(&mut self, ds: &Dataset, nodes: &[u32]) -> Batch {
        crate::graph::induced_edges(&ds.graph, nodes, &mut self.scratch, &mut self.edges);
        let edges = std::mem::take(&mut self.edges);
        let batch = self.assemble_with_edges(ds, nodes, &edges);
        self.edges = edges;
        batch
    }

    /// Assemble with an explicit (local-id) edge list — used by the
    /// GraphSAGE/VR-GCN baselines whose adjacency is *sampled*, not
    /// induced.
    pub fn assemble_with_edges(
        &mut self,
        ds: &Dataset,
        nodes: &[u32],
        edges: &[(u32, u32)],
    ) -> Batch {
        let b = self.b_max;
        let n_real = nodes.len();
        assert!(
            n_real <= b,
            "batch of {n_real} nodes exceeds b_max={b}; increase b_max \
             or reduce clusters per batch"
        );

        let mut a = Tensor::zeros(vec![b, b]);
        build_dense_block(n_real, edges, b, self.norm, &mut a.data);

        let f = ds.f_in;
        let c = ds.num_classes;
        let mut x = Tensor::zeros(vec![b, f]);
        let mut y = Tensor::zeros(vec![b, c]);
        let mut mask = Tensor::zeros(vec![b]);
        let mut n_train = 0;
        for (i, &v) in nodes.iter().enumerate() {
            let v = v as usize;
            x.data[i * f..(i + 1) * f].copy_from_slice(ds.feature_row(v));
            ds.labels.write_row(v, c, &mut y.data[i * c..(i + 1) * c]);
            if ds.split[v] == Split::Train {
                mask.data[i] = 1.0;
                n_train += 1;
            }
        }

        Batch {
            nodes: nodes.to_vec(),
            a,
            x,
            y,
            mask,
            n_real,
            within_edges: edges.len(),
            n_train,
        }
    }
}

impl Batch {
    /// Override the mask to select arbitrary nodes (e.g. eval over val
    /// nodes through the forward artifact).
    pub fn mask_for_split(&mut self, ds: &Dataset, want: Split) {
        self.mask.data.iter_mut().for_each(|m| *m = 0.0);
        for (i, &v) in self.nodes.iter().enumerate() {
            if ds.split[v as usize] == want {
                self.mask.data[i] = 1.0;
            }
        }
    }

    /// Host bytes of the batch tensors (memory accounting, Table 5).
    pub fn bytes(&self) -> usize {
        self.a.size_bytes() + self.x.size_bytes() + self.y.size_bytes()
            + self.mask.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{build, preset};
    use crate::norm::NormConfig;

    fn small_ds() -> Dataset {
        build(preset("cora_like").unwrap(), 1)
    }

    #[test]
    fn assembles_padded_batch() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 512, NormConfig::PAPER_DEFAULT);
        let nodes: Vec<u32> = (0..300u32).collect();
        let b = asm.assemble(&ds, &nodes);
        assert_eq!(b.n_real, 300);
        assert_eq!(b.a.dims, vec![512, 512]);
        assert_eq!(b.x.dims, vec![512, ds.f_in]);
        assert_eq!(b.y.dims, vec![512, ds.num_classes]);
        // padding rows of A are zero
        for i in 300..512 {
            assert!(b.a.data[i * 512..(i + 1) * 512].iter().all(|&v| v == 0.0));
        }
        // mask only over train nodes
        let expect: f32 = nodes
            .iter()
            .map(|&v| (ds.split[v as usize] == Split::Train) as u32 as f32)
            .sum();
        assert_eq!(b.mask.data.iter().sum::<f32>(), expect);
        assert_eq!(b.n_train as f32, expect);
    }

    #[test]
    fn features_and_labels_copied() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 512, NormConfig::PAPER_DEFAULT);
        let nodes = vec![7u32, 100, 2000];
        let b = asm.assemble(&ds, &nodes);
        for (i, &v) in nodes.iter().enumerate() {
            assert_eq!(
                &b.x.data[i * ds.f_in..(i + 1) * ds.f_in],
                ds.feature_row(v as usize)
            );
            let cls = ds.labels.class_of(v as usize).unwrap() as usize;
            assert_eq!(b.y.data[i * ds.num_classes + cls], 1.0);
        }
    }

    #[test]
    fn reuse_across_batches() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 256, NormConfig::ROW);
        let b1 = asm.assemble(&ds, &(0..200u32).collect::<Vec<_>>());
        let b2 = asm.assemble(&ds, &(200..280u32).collect::<Vec<_>>());
        assert_eq!(b1.n_real, 200);
        assert_eq!(b2.n_real, 80);
        // row-normalized: each real row of A sums to ~1 (or enhanced)
        for i in 0..b2.n_real {
            let s: f32 = b2.a.data[i * 256..(i + 1) * 256].iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums {s}");
        }
    }

    #[test]
    #[should_panic(expected = "exceeds b_max")]
    fn oversize_batch_panics() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 128, NormConfig::PAPER_DEFAULT);
        let nodes: Vec<u32> = (0..200u32).collect();
        asm.assemble(&ds, &nodes);
    }

    #[test]
    fn mask_for_split_switches() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 512, NormConfig::PAPER_DEFAULT);
        let nodes: Vec<u32> = (0..400u32).collect();
        let mut b = asm.assemble(&ds, &nodes);
        b.mask_for_split(&ds, Split::Val);
        let expect: f32 = nodes
            .iter()
            .map(|&v| (ds.split[v as usize] == Split::Val) as u32 as f32)
            .sum();
        assert_eq!(b.mask.data.iter().sum::<f32>(), expect);
    }
}
