//! Batch assembly: node union → induced adjacency block (with the
//! between-cluster links added back, §3.2) → per-batch renormalization
//! (§6.2) → padded dense tensors for the AOT executable.
//!
//! This is the L3 hot path.  [`BatchAssembler::assemble_into`] writes
//! into a caller-owned reusable [`Batch`]: the `a/x/y/mask` tensors and
//! the `nodes` list keep their allocations across steps, only the rows
//! dirtied by the *previous* batch are cleared (tracked per `Batch`, so
//! the trainer can double-buffer two batches through one assembler),
//! and the degree scratch for the dense-block normalization lives in
//! the assembler.  Steady-state assembly performs no heap allocation.
//! The owning `assemble`/`assemble_with_edges` wrappers allocate a
//! fresh `Batch` per call and remain for one-off callers and tests.
//!
//! Batches are **sparse-native**: alongside the padded dense tensors
//! the PJRT executables consume, every assembly also fills a CSR
//! [`SparseBlock`] view of the same normalized adjacency block.  The
//! host backend trains and infers directly on that CSR (no
//! densify→re-sparsify round trip per step); both views value their
//! entries through `norm::block_edge_val`/`block_diag_val`, so they are
//! bit-identical by construction.

use crate::graph::{Dataset, GraphStorage, Split, SubgraphScratch};
use crate::norm::{
    block_diag_val, block_edge_val, build_dense_block_prezeroed, NormConfig,
};
use crate::runtime::Tensor;

/// CSR view of one batch's normalized adjacency block: off-diagonal
/// entries in row-major order with ascending column ids, plus the
/// per-node diagonal (self-loop) value, shaped exactly like the
/// full-graph `normalize_sparse` output so the tiled kernels apply
/// unchanged.  Rebuilt in place by every assembly (buffers keep their
/// allocations); entry values are bit-identical to the dense block's.
#[derive(Clone, Debug, Default)]
pub struct SparseBlock {
    /// Row offsets into `cols`/`vals`, length `n_real + 1`.
    pub offsets: Vec<usize>,
    /// Local column ids, ascending within each row.
    pub cols: Vec<u32>,
    /// Normalized off-diagonal values aligned with `cols`.
    pub vals: Vec<f32>,
    /// Per-node diagonal values (incl. diagonal enhancement), length
    /// `n_real`.
    pub self_loop: Vec<f32>,
}

impl SparseBlock {
    /// Empty block (filled by the first assembly).
    pub fn new() -> SparseBlock {
        SparseBlock::default()
    }

    /// Number of real rows.
    pub fn n(&self) -> usize {
        self.self_loop.len()
    }

    /// Stored off-diagonal entries.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Host bytes of the CSR buffers.
    pub fn bytes(&self) -> usize {
        self.offsets.len() * std::mem::size_of::<usize>()
            + self.cols.len() * 4
            + self.vals.len() * 4
            + self.self_loop.len() * 4
    }
}

/// Assembled batch, ready to feed the train/eval executable.
pub struct Batch {
    /// global node ids (local index = position).
    pub nodes: Vec<u32>,
    /// (b_max, b_max) normalized adjacency block.
    pub a: Tensor,
    /// (b_max, f_in) features.
    pub x: Tensor,
    /// (b_max, classes) one-/multi-hot labels.
    pub y: Tensor,
    /// (b_max,) loss mask (1.0 = labeled training node).
    pub mask: Tensor,
    /// number of real (non-padding) nodes.
    pub n_real: usize,
    /// directed edges inside the batch (embedding utilization, §3.1).
    pub within_edges: usize,
    /// labeled nodes in the batch.
    pub n_train: usize,
    /// CSR view of the same normalized block (host-backend fast path);
    /// entries are bit-identical to the `n_real × n_real` prefix of `a`.
    pub block: SparseBlock,
    /// rows of a/x/y (and mask entries) possibly non-zero from the last
    /// assembly into this batch — the only region the next
    /// `assemble_into` needs to clear.  Invariant: callers mutating a
    /// batch in place (e.g. mask overrides) only touch rows < n_real.
    dirty_rows: usize,
}

impl Batch {
    /// Fresh zeroed batch shaped for `b_max` × (f_in, classes).
    pub fn new(b_max: usize, f_in: usize, classes: usize) -> Batch {
        Batch {
            nodes: Vec::new(),
            a: Tensor::zeros(vec![b_max, b_max]),
            x: Tensor::zeros(vec![b_max, f_in]),
            y: Tensor::zeros(vec![b_max, classes]),
            mask: Tensor::zeros(vec![b_max]),
            n_real: 0,
            within_edges: 0,
            n_train: 0,
            block: SparseBlock::new(),
            dirty_rows: 0,
        }
    }
}

pub struct BatchAssembler {
    pub b_max: usize,
    pub norm: NormConfig,
    scratch: SubgraphScratch,
    edges: Vec<(u32, u32)>,
    /// degree scratch for `build_dense_block_prezeroed`, reused across
    /// batches instead of a fresh Vec per call.  After each dense build
    /// it holds the per-node normalization *scales*, which the sparse
    /// block build reuses.
    deg: Vec<f32>,
    /// per-row write cursor for the CSR counting sort, reused across
    /// batches.
    cursor: Vec<usize>,
    /// neighbor-row scratch for storage-backed induced extraction,
    /// reused across batches.
    nb: Vec<u32>,
}

/// Row-level access the assembly core needs.  Implemented by the in-RAM
/// [`Dataset`] and the [`GraphStorage`] seam so one core serves both
/// storage modes — the ram and disk paths cannot drift numerically
/// because they *are* the same code.
trait AssemblyRows {
    fn f_in(&self) -> usize;
    fn num_classes(&self) -> usize;
    fn copy_feature_row(&self, v: usize, out: &mut [f32]);
    fn write_label_row(&self, v: usize, classes: usize, out: &mut [f32]);
    fn is_train(&self, v: usize) -> bool;
}

impl AssemblyRows for Dataset {
    fn f_in(&self) -> usize {
        self.f_in
    }
    fn num_classes(&self) -> usize {
        self.num_classes
    }
    fn copy_feature_row(&self, v: usize, out: &mut [f32]) {
        out.copy_from_slice(self.feature_row(v));
    }
    fn write_label_row(&self, v: usize, classes: usize, out: &mut [f32]) {
        self.labels.write_row(v, classes, out);
    }
    fn is_train(&self, v: usize) -> bool {
        self.split[v] == Split::Train
    }
}

impl AssemblyRows for GraphStorage {
    fn f_in(&self) -> usize {
        self.f_in()
    }
    fn num_classes(&self) -> usize {
        self.num_classes()
    }
    fn copy_feature_row(&self, v: usize, out: &mut [f32]) {
        self.feature_row_into(v, out);
    }
    fn write_label_row(&self, v: usize, classes: usize, out: &mut [f32]) {
        GraphStorage::write_label_row(self, v, classes, out);
    }
    fn is_train(&self, v: usize) -> bool {
        self.split_of(v) == Split::Train
    }
}

impl BatchAssembler {
    pub fn new(n_graph: usize, b_max: usize, norm: NormConfig) -> Self {
        BatchAssembler {
            b_max,
            norm,
            scratch: SubgraphScratch::new(n_graph),
            edges: Vec::new(),
            deg: Vec::new(),
            cursor: Vec::new(),
            nb: Vec::new(),
        }
    }

    /// A reusable batch shaped for this assembler and dataset.
    pub fn new_batch(&self, ds: &Dataset) -> Batch {
        Batch::new(self.b_max, ds.f_in, ds.num_classes)
    }

    /// A reusable batch shaped for this assembler and storage.
    pub fn new_batch_storage(&self, store: &GraphStorage) -> Batch {
        Batch::new(self.b_max, store.f_in(), store.num_classes())
    }

    /// Assemble a batch over `nodes` using the graph's induced edges.
    /// Allocating wrapper over [`BatchAssembler::assemble_into`].
    pub fn assemble(&mut self, ds: &Dataset, nodes: &[u32]) -> Batch {
        let mut batch = self.new_batch(ds);
        self.assemble_into(ds, nodes, &mut batch);
        batch
    }

    /// Assemble with an explicit (local-id) edge list — used by the
    /// GraphSAGE/VR-GCN baselines whose adjacency is *sampled*, not
    /// induced.  Allocating wrapper over
    /// [`BatchAssembler::assemble_with_edges_into`].
    pub fn assemble_with_edges(
        &mut self,
        ds: &Dataset,
        nodes: &[u32],
        edges: &[(u32, u32)],
    ) -> Batch {
        let mut batch = self.new_batch(ds);
        self.assemble_with_edges_into(ds, nodes, edges, &mut batch);
        batch
    }

    /// Assemble the induced batch over `nodes` into a reused `batch`
    /// (zero steady-state allocation).
    pub fn assemble_into(&mut self, ds: &Dataset, nodes: &[u32], batch: &mut Batch) {
        // chaos-only latency fault (stalls assembly to stress the
        // prefetch overlap); one untaken branch when disabled
        crate::util::failpoint::maybe_delay("batch.assemble", 2);
        crate::graph::induced_edges(&ds.graph, nodes, &mut self.scratch, &mut self.edges);
        let edges = std::mem::take(&mut self.edges);
        self.assemble_with_edges_into(ds, nodes, &edges, batch);
        self.edges = edges;
    }

    /// Storage-backed twin of [`BatchAssembler::assemble_into`]: the
    /// induced block is gathered through lazy adjacency-row reads
    /// ([`induced_edges_by`](crate::graph::induced_edges_by)) and the
    /// feature/label/mask rows come from the [`GraphStorage`] accessors.
    /// On the `InRam` arm (and on an `OnDisk` store of the same
    /// dataset) the result is bit-identical to `assemble_into` — same
    /// edge order, same core (pinned by the `store` test suite).
    pub fn assemble_storage_into(
        &mut self,
        store: &GraphStorage,
        nodes: &[u32],
        batch: &mut Batch,
    ) {
        crate::util::failpoint::maybe_delay("batch.assemble", 2);
        let mut nb = std::mem::take(&mut self.nb);
        let mut edges = std::mem::take(&mut self.edges);
        crate::graph::induced_edges_by(nodes, &mut self.scratch, &mut nb, &mut edges, |v, buf| {
            store.neighbors_into(v as usize, buf)
        });
        self.assemble_edges_core(store, nodes, &edges, batch);
        self.edges = edges;
        self.nb = nb;
    }

    /// Core assembly into a reused `batch`: clears only the rows the
    /// previous assembly dirtied, then writes the new block/rows.
    pub fn assemble_with_edges_into(
        &mut self,
        ds: &Dataset,
        nodes: &[u32],
        edges: &[(u32, u32)],
        batch: &mut Batch,
    ) {
        self.assemble_edges_core(ds, nodes, edges, batch)
    }

    /// The one assembly core, generic over row storage (see
    /// [`AssemblyRows`]): dense + sparse block build, feature/label row
    /// copies, train mask, dirty-row bookkeeping.
    fn assemble_edges_core<R: AssemblyRows>(
        &mut self,
        rows: &R,
        nodes: &[u32],
        edges: &[(u32, u32)],
        batch: &mut Batch,
    ) {
        let b = self.b_max;
        let n_real = nodes.len();
        assert!(
            n_real <= b,
            "batch of {n_real} nodes exceeds b_max={b}; increase b_max \
             or reduce clusters per batch"
        );
        let f = rows.f_in();
        let c = rows.num_classes();
        assert_eq!(batch.a.dims, vec![b, b], "batch shaped for a different assembler");
        assert_eq!(batch.x.dims, vec![b, f], "batch shaped for a different dataset");
        assert_eq!(batch.y.dims, vec![b, c], "batch shaped for a different dataset");

        let prev = batch.dirty_rows;
        // A is sparsely written (edges + diagonal): zero exactly the
        // previously-dirtied rows, not the full b_max² block.
        batch.a.data[..prev * b].fill(0.0);
        build_dense_block_prezeroed(n_real, edges, b, self.norm, &mut self.deg, &mut batch.a.data);
        // CSR view of the same block, valued from the scales `deg` now
        // holds — bit-identical to the dense entries just written.
        self.build_sparse_block(n_real, edges, &mut batch.block);

        for (i, &v) in nodes.iter().enumerate() {
            let v = v as usize;
            rows.copy_feature_row(v, &mut batch.x.data[i * f..(i + 1) * f]);
            rows.write_label_row(v, c, &mut batch.y.data[i * c..(i + 1) * c]);
        }
        // rows the previous batch used beyond this batch's extent
        if prev > n_real {
            batch.x.data[n_real * f..prev * f].fill(0.0);
            batch.y.data[n_real * c..prev * c].fill(0.0);
        }

        let mut n_train = 0;
        for (i, &v) in nodes.iter().enumerate() {
            if rows.is_train(v as usize) {
                batch.mask.data[i] = 1.0;
                n_train += 1;
            } else {
                batch.mask.data[i] = 0.0;
            }
        }
        if prev > n_real {
            batch.mask.data[n_real..prev].fill(0.0);
        }

        batch.nodes.clear();
        batch.nodes.extend_from_slice(nodes);
        batch.n_real = n_real;
        batch.within_edges = edges.len();
        batch.n_train = n_train;
        batch.dirty_rows = n_real;
    }

    /// Rebuild `blk` as the CSR view of the current block: counting
    /// sort of `edges` by row, columns sorted ascending within each
    /// row, entries valued from the normalization scales left in
    /// `self.deg` by the dense build.  Self-loop pairs (`u == u`) are
    /// skipped — the diagonal lives in `self_loop`, like the full-graph
    /// `normalize_sparse` layout.  All buffers are reused; steady-state
    /// assembly allocates nothing.
    ///
    /// Contract: `edges` contains no duplicate pairs — the dense block
    /// tolerates duplicates by overwriting, the CSR would double-count
    /// them and silently diverge from the dense view.  Enforced with a
    /// release-mode assert after the per-row sort (O(nnz), trivial next
    /// to the sort itself).
    fn build_sparse_block(&mut self, n_real: usize, edges: &[(u32, u32)], blk: &mut SparseBlock) {
        blk.offsets.clear();
        blk.offsets.resize(n_real + 1, 0);
        for &(u, v) in edges {
            if u != v {
                blk.offsets[u as usize + 1] += 1;
            }
        }
        for i in 0..n_real {
            blk.offsets[i + 1] += blk.offsets[i];
        }
        let nnz = blk.offsets[n_real];

        blk.cols.clear();
        blk.cols.resize(nnz, 0);
        self.cursor.clear();
        self.cursor.extend_from_slice(&blk.offsets[..n_real]);
        for &(u, v) in edges {
            if u != v {
                let c = &mut self.cursor[u as usize];
                blk.cols[*c] = v;
                *c += 1;
            }
        }
        for i in 0..n_real {
            blk.cols[blk.offsets[i]..blk.offsets[i + 1]].sort_unstable();
        }

        blk.vals.clear();
        blk.vals.reserve(nnz);
        for u in 0..n_real {
            let su = self.deg[u];
            let row = &blk.cols[blk.offsets[u]..blk.offsets[u + 1]];
            assert!(
                row.windows(2).all(|w| w[0] < w[1]),
                "duplicate edge in batch row {u}: the CSR block would \
                 double-count what the dense block overwrites"
            );
            for &v in row {
                blk.vals.push(block_edge_val(self.norm, su, self.deg[v as usize]));
            }
        }
        blk.self_loop.clear();
        blk.self_loop.reserve(n_real);
        for i in 0..n_real {
            blk.self_loop.push(block_diag_val(self.norm, self.deg[i]));
        }
    }
}

impl Batch {
    /// Override the mask to select arbitrary nodes (e.g. eval over val
    /// nodes through the forward artifact).
    pub fn mask_for_split(&mut self, ds: &Dataset, want: Split) {
        self.mask.data.iter_mut().for_each(|m| *m = 0.0);
        for (i, &v) in self.nodes.iter().enumerate() {
            if ds.split[v as usize] == want {
                self.mask.data[i] = 1.0;
            }
        }
    }

    /// Scatter the batch's global→local node mapping into a
    /// caller-owned position table: `pos[nodes[i]] = i` for every local
    /// index `i`.  Entries for nodes outside the batch are left
    /// untouched, so a serving layer can reuse one `pos` buffer across
    /// flushes without clearing it (it only reads positions of nodes it
    /// just wrote).
    pub fn index_positions(&self, pos: &mut [u32]) {
        for (i, &v) in self.nodes.iter().enumerate() {
            pos[v as usize] = i as u32;
        }
    }

    /// Host bytes of the batch tensors + the CSR block view (memory
    /// accounting, Table 5).
    pub fn bytes(&self) -> usize {
        self.a.size_bytes() + self.x.size_bytes() + self.y.size_bytes()
            + self.mask.size_bytes() + self.block.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{build, preset};
    use crate::norm::NormConfig;

    fn small_ds() -> Dataset {
        build(preset("cora_like").unwrap(), 1)
    }

    #[test]
    fn assembles_padded_batch() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 512, NormConfig::PAPER_DEFAULT);
        let nodes: Vec<u32> = (0..300u32).collect();
        let b = asm.assemble(&ds, &nodes);
        assert_eq!(b.n_real, 300);
        assert_eq!(b.a.dims, vec![512, 512]);
        assert_eq!(b.x.dims, vec![512, ds.f_in]);
        assert_eq!(b.y.dims, vec![512, ds.num_classes]);
        // padding rows of A are zero
        for i in 300..512 {
            assert!(b.a.data[i * 512..(i + 1) * 512].iter().all(|&v| v == 0.0));
        }
        // mask only over train nodes
        let expect: f32 = nodes
            .iter()
            .map(|&v| (ds.split[v as usize] == Split::Train) as u32 as f32)
            .sum();
        assert_eq!(b.mask.data.iter().sum::<f32>(), expect);
        assert_eq!(b.n_train as f32, expect);
    }

    #[test]
    fn features_and_labels_copied() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 512, NormConfig::PAPER_DEFAULT);
        let nodes = vec![7u32, 100, 2000];
        let b = asm.assemble(&ds, &nodes);
        for (i, &v) in nodes.iter().enumerate() {
            assert_eq!(
                &b.x.data[i * ds.f_in..(i + 1) * ds.f_in],
                ds.feature_row(v as usize)
            );
            let cls = ds.labels.class_of(v as usize).unwrap() as usize;
            assert_eq!(b.y.data[i * ds.num_classes + cls], 1.0);
        }
    }

    #[test]
    fn reuse_across_batches() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 256, NormConfig::ROW);
        let b1 = asm.assemble(&ds, &(0..200u32).collect::<Vec<_>>());
        let b2 = asm.assemble(&ds, &(200..280u32).collect::<Vec<_>>());
        assert_eq!(b1.n_real, 200);
        assert_eq!(b2.n_real, 80);
        // row-normalized: each real row of A sums to ~1 (or enhanced)
        for i in 0..b2.n_real {
            let s: f32 = b2.a.data[i * 256..(i + 1) * 256].iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums {s}");
        }
    }

    /// The zero-allocation contract: assembling a smaller batch into a
    /// buffer previously used by a larger one must (a) not reallocate
    /// any tensor, and (b) produce exactly what a fresh assembly would
    /// — i.e. the dirty-row clearing leaves no stale state behind.
    #[test]
    fn reused_batch_matches_fresh_and_keeps_allocations() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 256, NormConfig::ROW);
        let big: Vec<u32> = (0..230u32).collect();
        let small: Vec<u32> = (500..560u32).collect();

        let mut reused = asm.new_batch(&ds);
        asm.assemble_into(&ds, &big, &mut reused);
        let ptrs = (
            reused.a.data.as_ptr(),
            reused.x.data.as_ptr(),
            reused.y.data.as_ptr(),
            reused.mask.data.as_ptr(),
        );
        let blk_caps = (
            reused.block.offsets.capacity(),
            reused.block.cols.capacity(),
            reused.block.vals.capacity(),
            reused.block.self_loop.capacity(),
        );
        let nodes_cap = reused.nodes.capacity();
        asm.assemble_into(&ds, &small, &mut reused);

        // (a) no reallocation of any batch tensor, the node list, or
        // the sparse-block buffers (the smaller batch fits them all)
        assert_eq!(ptrs.0, reused.a.data.as_ptr());
        assert_eq!(ptrs.1, reused.x.data.as_ptr());
        assert_eq!(ptrs.2, reused.y.data.as_ptr());
        assert_eq!(ptrs.3, reused.mask.data.as_ptr());
        assert_eq!(nodes_cap, reused.nodes.capacity());
        assert_eq!(blk_caps.0, reused.block.offsets.capacity());
        assert_eq!(blk_caps.1, reused.block.cols.capacity());
        assert_eq!(blk_caps.2, reused.block.vals.capacity());
        assert_eq!(blk_caps.3, reused.block.self_loop.capacity());

        // (b) bit-identical to a fresh assembly of the same nodes
        let fresh = asm.assemble(&ds, &small);
        assert_eq!(reused.nodes, fresh.nodes);
        assert_eq!(reused.a.data, fresh.a.data);
        assert_eq!(reused.x.data, fresh.x.data);
        assert_eq!(reused.y.data, fresh.y.data);
        assert_eq!(reused.mask.data, fresh.mask.data);
        assert_eq!(reused.n_real, fresh.n_real);
        assert_eq!(reused.n_train, fresh.n_train);
        assert_eq!(reused.within_edges, fresh.within_edges);
        assert_eq!(reused.block.offsets, fresh.block.offsets);
        assert_eq!(reused.block.cols, fresh.block.cols);
        assert_eq!(reused.block.vals, fresh.block.vals);
        assert_eq!(reused.block.self_loop, fresh.block.self_loop);
    }

    /// The sparse-native contract: the CSR block is exactly the
    /// `n_real × n_real` prefix of the dense tensor — same structure
    /// (every non-zero off-diagonal entry, ascending columns) and
    /// bit-identical values, across norm configs.
    #[test]
    fn sparse_block_matches_dense_prefix_bitwise() {
        let ds = small_ds();
        for norm in [NormConfig::PAPER_DEFAULT, NormConfig::ROW, NormConfig::ROW_LAMBDA1] {
            let mut asm = BatchAssembler::new(ds.n(), 256, norm);
            let nodes: Vec<u32> = (40..240u32).collect();
            let b = asm.assemble(&ds, &nodes);
            let n = b.n_real;
            let blk = &b.block;
            assert_eq!(blk.n(), n);
            assert_eq!(blk.offsets.len(), n + 1);
            let bm = 256;
            let mut seen = 0usize;
            for u in 0..n {
                let row = &blk.cols[blk.offsets[u]..blk.offsets[u + 1]];
                assert!(row.windows(2).all(|w| w[0] < w[1]), "row {u} not ascending");
                for (idx, &v) in row.iter().enumerate() {
                    let dense = b.a.data[u * bm + v as usize];
                    let sparse = blk.vals[blk.offsets[u] + idx];
                    assert_eq!(sparse.to_bits(), dense.to_bits(), "({u},{v})");
                    assert_ne!(v as usize, u, "diagonal stored as edge");
                    seen += 1;
                }
                assert_eq!(
                    blk.self_loop[u].to_bits(),
                    b.a.data[u * bm + u].to_bits(),
                    "diag {u}"
                );
                // no dense non-zero is missing from the CSR row
                let dense_nnz = b.a.data[u * bm..u * bm + n]
                    .iter()
                    .enumerate()
                    .filter(|&(v, &av)| v != u && av != 0.0)
                    .count();
                assert_eq!(dense_nnz, row.len(), "row {u} structure");
            }
            assert_eq!(seen, blk.nnz());
        }
    }

    /// Two batches double-buffered through one assembler must not see
    /// each other's dirty rows.
    #[test]
    fn double_buffered_batches_stay_independent() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 128, NormConfig::ROW);
        let mut ba = asm.new_batch(&ds);
        let mut bb = asm.new_batch(&ds);
        let sets: Vec<Vec<u32>> = vec![
            (0..100u32).collect(),
            (100..140u32).collect(),
            (140..160u32).collect(),
            (160..260u32).collect(),
        ];
        for (k, nodes) in sets.iter().enumerate() {
            let buf = if k % 2 == 0 { &mut ba } else { &mut bb };
            asm.assemble_into(&ds, nodes, buf);
            let fresh = asm.assemble(&ds, nodes);
            assert_eq!(buf.a.data, fresh.a.data, "set {k}");
            assert_eq!(buf.x.data, fresh.x.data, "set {k}");
            assert_eq!(buf.mask.data, fresh.mask.data, "set {k}");
        }
    }

    /// The storage twin over an `InRam` wrap is the same code path row
    /// for row — pin it bitwise anyway so a refactor of either entry
    /// point can't silently diverge (disk-arm parity lives in
    /// `tests/store.rs`).
    #[test]
    fn storage_assembly_matches_dataset_assembly() {
        let ds = small_ds();
        let store = GraphStorage::InRam(small_ds());
        let mut asm = BatchAssembler::new(ds.n(), 256, NormConfig::PAPER_DEFAULT);
        for nodes in [(0..200u32).collect::<Vec<_>>(), vec![5, 999, 17, 2000]] {
            let fresh = asm.assemble(&ds, &nodes);
            let mut got = asm.new_batch_storage(&store);
            asm.assemble_storage_into(&store, &nodes, &mut got);
            assert_eq!(got.nodes, fresh.nodes);
            assert_eq!(got.a.data, fresh.a.data);
            assert_eq!(got.x.data, fresh.x.data);
            assert_eq!(got.y.data, fresh.y.data);
            assert_eq!(got.mask.data, fresh.mask.data);
            assert_eq!(got.n_train, fresh.n_train);
            assert_eq!(got.within_edges, fresh.within_edges);
            assert_eq!(got.block.cols, fresh.block.cols);
            assert_eq!(got.block.vals, fresh.block.vals);
            assert_eq!(got.block.self_loop, fresh.block.self_loop);
        }
    }

    #[test]
    #[should_panic(expected = "exceeds b_max")]
    fn oversize_batch_panics() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 128, NormConfig::PAPER_DEFAULT);
        let nodes: Vec<u32> = (0..200u32).collect();
        asm.assemble(&ds, &nodes);
    }

    #[test]
    fn mask_for_split_switches() {
        let ds = small_ds();
        let mut asm = BatchAssembler::new(ds.n(), 512, NormConfig::PAPER_DEFAULT);
        let nodes: Vec<u32> = (0..400u32).collect();
        let mut b = asm.assemble(&ds, &nodes);
        b.mask_for_split(&ds, Split::Val);
        let expect: f32 = nodes
            .iter()
            .map(|&v| (ds.split[v as usize] == Split::Val) as u32 as f32)
            .sum();
        assert_eq!(b.mask.data.iter().sum::<f32>(), expect);
    }
}
