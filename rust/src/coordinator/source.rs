//! [`BatchSource`]: the pull side of the training loop.  A source owns
//! everything batch-shaped about one training method — the epoch plan,
//! the node sampling, the [`BatchAssembler`] and its reusable scratch —
//! and exposes it as *assemble batch `i` of this epoch into this
//! buffer*.  The [`crate::session::Driver`] pulls steps through
//! [`crate::runtime::Backend::step_from`], which is where the backend
//! combinators hook in: [`crate::runtime::PrefetchBackend`] assembles
//! batch `i + 1` on a helper thread while batch `i` executes, and
//! [`crate::runtime::ShardedBackend`] pulls one batch per replica for a
//! data-parallel step — every [`BatchSource`]-backed method gets both
//! for free.
//!
//! Sources are `Send` so a combinator may drive `assemble` from a
//! scoped helper thread; assembly for index `i` is only ever in flight
//! on one thread at a time (the call contract below).
#![deny(missing_docs)]

use anyhow::{anyhow, Result};

use crate::coordinator::batch::{Batch, BatchAssembler};
use crate::coordinator::sampler::ClusterSampler;
use crate::graph::Dataset;
use crate::norm::NormConfig;
use crate::runtime::ModelSpec;
use crate::util::Rng;

/// Accumulated per-run accounting a source collects while assembling,
/// read once by the driver when packaging the
/// [`crate::coordinator::trainer::TrainResult`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SourceStats {
    /// Largest `batch bytes (+ method-specific activation estimate)`
    /// seen — the batch half of the Table 5 peak-memory accounting (the
    /// driver adds the parameter/optimizer bytes).
    pub max_batch_bytes: usize,
    /// Method-specific utilization ratio reported as
    /// `TrainResult::avg_within_edges_per_node`: within-batch directed
    /// edges per batch node for Cluster-GCN, mean sampled-union size
    /// per batch for GraphSAGE, 0 for the others.
    pub utilization: f64,
}

/// Per-epoch RNG derivation shared by every source: the stream is a
/// pure function of `(seed, salt, epoch)`, never of how many batches
/// earlier epochs consumed.  This is what makes a checkpoint
/// save→resume through the driver replay the *same* epoch streams as
/// an uninterrupted run.
pub fn epoch_rng(seed: u64, salt: u64, epoch: usize) -> Rng {
    Rng::new(seed ^ salt ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// A per-epoch stream of assembled [`Batch`]es — the training loop's
/// pull side, implemented by Cluster-GCN ([`ClusterSource`]) and the
/// batch-based baselines (`baselines::{ExpansionSource, SageSource}`).
///
/// Call contract (upheld by the driver and the backend combinators):
/// [`BatchSource::begin_epoch`] once per epoch, then
/// [`BatchSource::assemble`] for indices `0..len()`, each index at most
/// once, in ascending order — though a combinator may run index `i + 1`
/// on a helper thread while batch `i` executes (which is why the trait
/// is `Send`).  A future source whose assembly depends on the
/// *results* of earlier steps must return `false` from
/// [`BatchSource::prefetchable`] to disable lookahead (no current
/// source needs it: the step-coupled method, VR-GCN, bypasses
/// `BatchSource` entirely and runs inline in the driver).
pub trait BatchSource: Send {
    /// `(b_max, f_in, classes)` shaping every batch this source
    /// assembles — what combinator-owned buffers are sized from.
    fn shape(&self) -> (usize, usize, usize);

    /// A fresh zeroed buffer shaped by [`BatchSource::shape`].
    fn new_batch(&self) -> Batch {
        let (b, f, c) = self.shape();
        Batch::new(b, f, c)
    }

    /// Start epoch `epoch` (1-based): draw the epoch plan and return
    /// the number of batches it holds.  The plan stream is derived via
    /// [`epoch_rng`], so it is a pure function of `(seed, epoch)`.
    fn begin_epoch(&mut self, epoch: usize) -> usize;

    /// Batches in the current epoch's plan.
    fn len(&self) -> usize;

    /// True when the current epoch has no batches.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether assembling batch `i + 1` before batch `i`'s step has
    /// completed preserves semantics.  `true` for sources whose
    /// assembly depends only on the epoch plan and their own RNG.
    fn prefetchable(&self) -> bool {
        true
    }

    /// Assemble batch `i` of the current epoch into `into` (a buffer
    /// from [`BatchSource::new_batch`], reused across steps).
    fn assemble(&mut self, i: usize, into: &mut Batch);

    /// Accounting accumulated so far (see [`SourceStats`]).
    fn stats(&self) -> SourceStats;
}

/// Cluster-GCN's source (Algorithm 1 line 3): per epoch, a shuffled
/// without-replacement plan of q-cluster batches from the
/// [`ClusterSampler`]; per batch, the concatenated cluster union
/// assembled with between-cluster links restored and renormalized.
pub struct ClusterSource<'a> {
    ds: &'a Dataset,
    sampler: ClusterSampler,
    assembler: BatchAssembler,
    seed: u64,
    plan: Vec<Vec<u32>>,
    nodes: Vec<u32>,
    within_edges: u64,
    batch_nodes: u64,
    max_batch_bytes: usize,
}

impl<'a> ClusterSource<'a> {
    /// Source over `ds` with an owned sampler; errors when the largest
    /// possible batch cannot fit the model's padded batch size.
    pub fn new(
        ds: &'a Dataset,
        sampler: ClusterSampler,
        spec: &ModelSpec,
        norm: NormConfig,
        seed: u64,
    ) -> Result<ClusterSource<'a>> {
        if sampler.max_batch_nodes() > spec.b_max {
            return Err(anyhow!(
                "sampler can produce {} nodes but the model has b_max={}",
                sampler.max_batch_nodes(),
                spec.b_max
            ));
        }
        Ok(ClusterSource {
            ds,
            sampler,
            assembler: BatchAssembler::new(ds.n(), spec.b_max, norm),
            seed,
            plan: Vec::new(),
            nodes: Vec::new(),
            within_edges: 0,
            batch_nodes: 0,
            max_batch_bytes: 0,
        })
    }
}

impl BatchSource for ClusterSource<'_> {
    fn shape(&self) -> (usize, usize, usize) {
        (self.assembler.b_max, self.ds.f_in, self.ds.num_classes)
    }

    fn begin_epoch(&mut self, epoch: usize) -> usize {
        let mut rng = epoch_rng(self.seed, 0x5A5A_0000_1111_2222, epoch);
        self.plan = self.sampler.epoch_plan(&mut rng);
        self.plan.len()
    }

    fn len(&self) -> usize {
        self.plan.len()
    }

    fn assemble(&mut self, i: usize, into: &mut Batch) {
        self.sampler.batch_nodes(&self.plan[i], &mut self.nodes);
        self.assembler.assemble_into(self.ds, &self.nodes, into);
        if into.n_train > 0 {
            self.within_edges += into.within_edges as u64;
            self.batch_nodes += into.n_real as u64;
            self.max_batch_bytes = self.max_batch_bytes.max(into.bytes());
        }
    }

    fn stats(&self) -> SourceStats {
        SourceStats {
            max_batch_bytes: self.max_batch_bytes,
            utilization: self.within_edges as f64 / self.batch_nodes.max(1) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norm::NormConfig;
    use crate::partition::{parts_to_clusters, Partitioner, RandomPartitioner};

    fn source(seed: u64) -> (Dataset, ModelSpec) {
        let ds = crate::datagen::build(crate::datagen::preset("cora_like").unwrap(), seed);
        let spec = crate::runtime::ModelSpec::gcn(
            ds.task,
            2,
            ds.f_in,
            16,
            ds.num_classes,
            ds.n().next_multiple_of(8),
        );
        (ds, spec)
    }

    #[test]
    fn epoch_plans_are_replayable_per_epoch() {
        let (ds, spec) = source(3);
        let mut rng = Rng::new(9);
        let part = RandomPartitioner.partition(&ds.graph, 8, &mut rng);
        let sampler = ClusterSampler::new(parts_to_clusters(&part, 8), 2);
        let mk = || {
            ClusterSource::new(&ds, sampler.clone(), &spec, NormConfig::PAPER_DEFAULT, 7).unwrap()
        };
        let mut a = mk();
        let mut b = mk();
        // same (seed, epoch) -> same plan, independent of what epoch the
        // other source ran before
        a.begin_epoch(1);
        a.begin_epoch(4);
        b.begin_epoch(4);
        assert_eq!(a.plan, b.plan);
        // batches assemble identically
        let n = a.len();
        assert!(n > 0);
        let mut ba = a.new_batch();
        let mut bb = b.new_batch();
        for i in 0..n {
            a.assemble(i, &mut ba);
            b.assemble(i, &mut bb);
            assert_eq!(ba.nodes, bb.nodes, "batch {i}");
            assert_eq!(ba.a.data, bb.a.data, "batch {i}");
        }
    }

    #[test]
    fn oversized_sampler_is_rejected() {
        let (ds, _) = source(1);
        let clusters = vec![(0..ds.n() as u32).collect::<Vec<_>>()];
        let sampler = ClusterSampler::new(clusters, 1);
        let spec = ModelSpec::gcn(ds.task, 2, ds.f_in, 16, ds.num_classes, 8);
        assert!(
            ClusterSource::new(&ds, sampler, &spec, NormConfig::PAPER_DEFAULT, 0).is_err()
        );
    }
}
