//! [`BatchSource`]: the pull side of the training loop.  A source owns
//! everything batch-shaped about one training method — the epoch plan,
//! the node sampling, the [`BatchAssembler`] and its reusable scratch —
//! and exposes it as *assemble batch `i` of this epoch into this
//! buffer*.  The [`crate::session::Driver`] pulls steps through
//! [`crate::runtime::Backend::step_from`], which is where the backend
//! combinators hook in: [`crate::runtime::PrefetchBackend`] assembles
//! batch `i + 1` on a helper thread while batch `i` executes, and
//! [`crate::runtime::ShardedBackend`] pulls one batch per replica for a
//! data-parallel step — every [`BatchSource`]-backed method gets both
//! for free.
//!
//! Sources are `Send` so a combinator may drive `assemble` from a
//! scoped helper thread; assembly for index `i` is only ever in flight
//! on one thread at a time (the call contract below).
#![deny(missing_docs)]

use anyhow::{anyhow, Result};

use crate::coordinator::batch::{Batch, BatchAssembler};
use crate::coordinator::sampler::ClusterSampler;
use crate::graph::Dataset;
use crate::norm::NormConfig;
use crate::runtime::ModelSpec;
use crate::util::Rng;

/// Accumulated per-run accounting a source collects while assembling,
/// read once by the driver when packaging the
/// [`crate::coordinator::trainer::TrainResult`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SourceStats {
    /// Largest `batch bytes (+ method-specific activation estimate)`
    /// seen — the batch half of the Table 5 peak-memory accounting (the
    /// driver adds the parameter/optimizer bytes).
    pub max_batch_bytes: usize,
    /// Method-specific utilization ratio reported as
    /// `TrainResult::avg_within_edges_per_node`: within-batch directed
    /// edges per batch node for Cluster-GCN, mean sampled-union size
    /// per batch for GraphSAGE, 0 for the others.
    pub utilization: f64,
}

/// Per-epoch RNG derivation shared by every source: the stream is a
/// pure function of `(seed, salt, epoch)`, never of how many batches
/// earlier epochs consumed.  This is what makes a checkpoint
/// save→resume through the driver replay the *same* epoch streams as
/// an uninterrupted run.
pub fn epoch_rng(seed: u64, salt: u64, epoch: usize) -> Rng {
    Rng::new(seed ^ salt ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// A per-epoch stream of assembled [`Batch`]es — the training loop's
/// pull side, implemented by Cluster-GCN ([`ClusterSource`]) and the
/// batch-based baselines (`baselines::{ExpansionSource, SageSource}`).
///
/// Call contract (upheld by the driver and the backend combinators):
/// [`BatchSource::begin_epoch`] once per epoch, then
/// [`BatchSource::assemble`] for indices `0..len()`, each index at most
/// once, in ascending order — though a combinator may run index `i + 1`
/// on a helper thread while batch `i` executes (which is why the trait
/// is `Send`).  A future source whose assembly depends on the
/// *results* of earlier steps must return `false` from
/// [`BatchSource::prefetchable`] to disable lookahead (no current
/// source needs it: the step-coupled method, VR-GCN, bypasses
/// `BatchSource` entirely and runs inline in the driver).
pub trait BatchSource: Send {
    /// `(b_max, f_in, classes)` shaping every batch this source
    /// assembles — what combinator-owned buffers are sized from.
    fn shape(&self) -> (usize, usize, usize);

    /// A fresh zeroed buffer shaped by [`BatchSource::shape`].
    fn new_batch(&self) -> Batch {
        let (b, f, c) = self.shape();
        Batch::new(b, f, c)
    }

    /// Start epoch `epoch` (1-based): draw the epoch plan and return
    /// the number of batches it holds.  The plan stream is derived via
    /// [`epoch_rng`], so it is a pure function of `(seed, epoch)`.
    fn begin_epoch(&mut self, epoch: usize) -> usize;

    /// Batches in the current epoch's plan.
    fn len(&self) -> usize;

    /// True when the current epoch has no batches.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether assembling batch `i + 1` before batch `i`'s step has
    /// completed preserves semantics.  `true` for sources whose
    /// assembly depends only on the epoch plan and their own RNG.
    fn prefetchable(&self) -> bool {
        true
    }

    /// The epoch most recently started via [`BatchSource::begin_epoch`]
    /// (0 before the first epoch).  The distributed backend stamps this
    /// into its step requests so workers replay the same epoch plan.
    fn epoch(&self) -> usize {
        0
    }

    /// Which distributed worker owns batch `i` of the current epoch
    /// plan (always 0 for sources without per-worker ownership).  A
    /// source built with `n_workers > 1` interleaves per-worker plans
    /// round-robin and the distributed backend routes batch `i` to
    /// worker `owner_of(i)`, which assembles it from its own clusters.
    fn owner_of(&self, i: usize) -> usize {
        let _ = i;
        0
    }

    /// Assemble batch `i` of the current epoch into `into` (a buffer
    /// from [`BatchSource::new_batch`], reused across steps).
    fn assemble(&mut self, i: usize, into: &mut Batch);

    /// Accounting accumulated so far (see [`SourceStats`]).
    fn stats(&self) -> SourceStats;
}

/// Epoch-plan RNG salt of the Cluster-GCN source.  Worker `w`'s
/// sub-plan mixes `w` into the salt ([`worker_salt`]) so the per-worker
/// shuffles are independent streams; worker 0's salt is exactly this
/// constant, which keeps the single-worker plan bit-identical to the
/// pre-distributed stream.
const CLUSTER_PLAN_SALT: u64 = 0x5A5A_0000_1111_2222;

/// Plan salt for distributed worker `w` (see [`CLUSTER_PLAN_SALT`]).
fn worker_salt(w: usize) -> u64 {
    CLUSTER_PLAN_SALT ^ (w as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)
}

/// Cluster-GCN's source (Algorithm 1 line 3): per epoch, a shuffled
/// without-replacement plan of q-cluster batches from the
/// [`ClusterSampler`]; per batch, the concatenated cluster union
/// assembled with between-cluster links restored and renormalized.
///
/// With [`ClusterSource::new_distributed`] the source carries one
/// sub-sampler per distributed worker (cluster `c` is owned by worker
/// `c % n_workers`) and the epoch plan interleaves the per-worker
/// plans round-robin; every process of a distributed run derives the
/// identical plan from `(seed, epoch)`, and each batch records which
/// worker must assemble it ([`BatchSource::owner_of`]).
pub struct ClusterSource<'a> {
    ds: &'a Dataset,
    /// One sampler per worker; a non-distributed source has exactly one.
    samplers: Vec<ClusterSampler>,
    assembler: BatchAssembler,
    seed: u64,
    /// `(owner worker, cluster ids local to that worker's sampler)`.
    plan: Vec<(u32, Vec<u32>)>,
    epoch: usize,
    nodes: Vec<u32>,
    within_edges: u64,
    batch_nodes: u64,
    max_batch_bytes: usize,
}

impl<'a> ClusterSource<'a> {
    /// Source over `ds` with an owned sampler; errors when the largest
    /// possible batch cannot fit the model's padded batch size.
    pub fn new(
        ds: &'a Dataset,
        sampler: ClusterSampler,
        spec: &ModelSpec,
        norm: NormConfig,
        seed: u64,
    ) -> Result<ClusterSource<'a>> {
        Self::from_samplers(ds, vec![sampler], spec, norm, seed)
    }

    /// Distributed variant: split the sampler's clusters by ownership
    /// (`cluster c -> worker c % n_workers`) into one sub-sampler per
    /// worker.  Each worker keeps the global `q` clamped to its owned
    /// cluster count; `n_workers = 1` is exactly [`ClusterSource::new`].
    pub fn new_distributed(
        ds: &'a Dataset,
        sampler: ClusterSampler,
        spec: &ModelSpec,
        norm: NormConfig,
        seed: u64,
        n_workers: usize,
    ) -> Result<ClusterSource<'a>> {
        if n_workers <= 1 {
            return Self::new(ds, sampler, spec, norm, seed);
        }
        if n_workers > sampler.clusters.len() {
            return Err(anyhow!(
                "{} workers but only {} clusters; every worker must own \
                 at least one cluster (lower --workers or raise --parts)",
                n_workers,
                sampler.clusters.len()
            ));
        }
        let q = sampler.q;
        let mut owned: Vec<Vec<Vec<u32>>> = (0..n_workers).map(|_| Vec::new()).collect();
        for (c, nodes) in sampler.clusters.into_iter().enumerate() {
            owned[c % n_workers].push(nodes);
        }
        let samplers = owned
            .into_iter()
            .map(|clusters| {
                let qw = q.min(clusters.len());
                ClusterSampler::new(clusters, qw)
            })
            .collect();
        Self::from_samplers(ds, samplers, spec, norm, seed)
    }

    fn from_samplers(
        ds: &'a Dataset,
        samplers: Vec<ClusterSampler>,
        spec: &ModelSpec,
        norm: NormConfig,
        seed: u64,
    ) -> Result<ClusterSource<'a>> {
        for s in &samplers {
            if s.max_batch_nodes() > spec.b_max {
                return Err(anyhow!(
                    "sampler can produce {} nodes but the model has b_max={}",
                    s.max_batch_nodes(),
                    spec.b_max
                ));
            }
        }
        Ok(ClusterSource {
            ds,
            samplers,
            assembler: BatchAssembler::new(ds.n(), spec.b_max, norm),
            seed,
            plan: Vec::new(),
            epoch: 0,
            nodes: Vec::new(),
            within_edges: 0,
            batch_nodes: 0,
            max_batch_bytes: 0,
        })
    }

    /// Number of distributed workers this source plans for (1 when not
    /// distributed).
    pub fn n_workers(&self) -> usize {
        self.samplers.len()
    }
}

impl BatchSource for ClusterSource<'_> {
    fn shape(&self) -> (usize, usize, usize) {
        (self.assembler.b_max, self.ds.f_in, self.ds.num_classes)
    }

    fn begin_epoch(&mut self, epoch: usize) -> usize {
        self.epoch = epoch;
        self.plan.clear();
        if self.samplers.len() == 1 {
            let mut rng = epoch_rng(self.seed, CLUSTER_PLAN_SALT, epoch);
            self.plan
                .extend(self.samplers[0].epoch_plan(&mut rng).into_iter().map(|g| (0, g)));
        } else {
            // per-worker plans from independent streams, interleaved
            // round-robin so one step's W batches hit W distinct workers
            let plans: Vec<Vec<Vec<u32>>> = self
                .samplers
                .iter()
                .enumerate()
                .map(|(w, s)| {
                    let mut rng = epoch_rng(self.seed, worker_salt(w), epoch);
                    s.epoch_plan(&mut rng)
                })
                .collect();
            let rounds = plans.iter().map(Vec::len).max().unwrap_or(0);
            for r in 0..rounds {
                for (w, p) in plans.iter().enumerate() {
                    if let Some(g) = p.get(r) {
                        self.plan.push((w as u32, g.clone()));
                    }
                }
            }
        }
        self.plan.len()
    }

    fn len(&self) -> usize {
        self.plan.len()
    }

    fn epoch(&self) -> usize {
        self.epoch
    }

    fn owner_of(&self, i: usize) -> usize {
        self.plan[i].0 as usize
    }

    fn assemble(&mut self, i: usize, into: &mut Batch) {
        let (w, group) = &self.plan[i];
        self.samplers[*w as usize].batch_nodes(group, &mut self.nodes);
        self.assembler.assemble_into(self.ds, &self.nodes, into);
        if into.n_train > 0 {
            self.within_edges += into.within_edges as u64;
            self.batch_nodes += into.n_real as u64;
            self.max_batch_bytes = self.max_batch_bytes.max(into.bytes());
        }
    }

    fn stats(&self) -> SourceStats {
        SourceStats {
            max_batch_bytes: self.max_batch_bytes,
            utilization: self.within_edges as f64 / self.batch_nodes.max(1) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norm::NormConfig;
    use crate::partition::{parts_to_clusters, Partitioner, RandomPartitioner};

    fn source(seed: u64) -> (Dataset, ModelSpec) {
        let ds = crate::datagen::build(crate::datagen::preset("cora_like").unwrap(), seed);
        let spec = crate::runtime::ModelSpec::gcn(
            ds.task,
            2,
            ds.f_in,
            16,
            ds.num_classes,
            ds.n().next_multiple_of(8),
        );
        (ds, spec)
    }

    #[test]
    fn epoch_plans_are_replayable_per_epoch() {
        let (ds, spec) = source(3);
        let mut rng = Rng::new(9);
        let part = RandomPartitioner.partition(&ds.graph, 8, &mut rng);
        let sampler = ClusterSampler::new(parts_to_clusters(&part, 8), 2);
        let mk = || {
            ClusterSource::new(&ds, sampler.clone(), &spec, NormConfig::PAPER_DEFAULT, 7).unwrap()
        };
        let mut a = mk();
        let mut b = mk();
        // same (seed, epoch) -> same plan, independent of what epoch the
        // other source ran before
        a.begin_epoch(1);
        a.begin_epoch(4);
        b.begin_epoch(4);
        assert_eq!(a.plan, b.plan);
        // batches assemble identically
        let n = a.len();
        assert!(n > 0);
        let mut ba = a.new_batch();
        let mut bb = b.new_batch();
        for i in 0..n {
            a.assemble(i, &mut ba);
            b.assemble(i, &mut bb);
            assert_eq!(ba.nodes, bb.nodes, "batch {i}");
            assert_eq!(ba.a.data, bb.a.data, "batch {i}");
        }
    }

    /// `new_distributed(n_workers = 1)` must be the plain source: same
    /// plan stream, same batches — this underwrites the workers=1
    /// bit-parity contract of the distributed backend.
    #[test]
    fn single_worker_distributed_plan_matches_plain() {
        let (ds, spec) = source(5);
        let mut rng = Rng::new(11);
        let part = RandomPartitioner.partition(&ds.graph, 8, &mut rng);
        let sampler = ClusterSampler::new(parts_to_clusters(&part, 8), 2);
        let mut plain =
            ClusterSource::new(&ds, sampler.clone(), &spec, NormConfig::PAPER_DEFAULT, 7).unwrap();
        let mut dist =
            ClusterSource::new_distributed(&ds, sampler, &spec, NormConfig::PAPER_DEFAULT, 7, 1)
                .unwrap();
        for epoch in 1..=3 {
            assert_eq!(plain.begin_epoch(epoch), dist.begin_epoch(epoch));
            assert_eq!(plain.plan, dist.plan, "epoch {epoch}");
            assert_eq!(dist.epoch(), epoch);
        }
    }

    /// Distributed plans interleave worker sub-plans round-robin, every
    /// batch is assembled from its owner's clusters only, and ownership
    /// respects `c % n_workers`.
    #[test]
    fn distributed_plan_interleaves_owners() {
        let (ds, spec) = source(5);
        let mut rng = Rng::new(11);
        let parts = 9;
        let part = RandomPartitioner.partition(&ds.graph, parts, &mut rng);
        let clusters = parts_to_clusters(&part, parts);
        let sampler = ClusterSampler::new(clusters.clone(), 2);
        let mut src = ClusterSource::new_distributed(
            &ds,
            sampler,
            &spec,
            NormConfig::PAPER_DEFAULT,
            7,
            3,
        )
        .unwrap();
        assert_eq!(src.n_workers(), 3);
        let n = src.begin_epoch(1);
        assert!(n >= 3, "n={n}");
        // round-robin: the first three batches hit three distinct workers
        assert_eq!(
            (0..3).map(|i| src.owner_of(i)).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        // each batch's nodes come only from clusters owned by its worker
        // (worker w owns global clusters c with c % 3 == w)
        let mut batch = src.new_batch();
        for i in 0..n {
            let w = src.owner_of(i);
            src.assemble(i, &mut batch);
            for v in &batch.nodes {
                let c = clusters.iter().position(|cl| cl.contains(v)).unwrap();
                assert_eq!(c % 3, w, "batch {i} node {v} from cluster {c}");
            }
        }
    }

    /// More workers than clusters cannot give every worker a cluster.
    #[test]
    fn too_many_workers_rejected() {
        let (ds, spec) = source(5);
        let clusters: Vec<Vec<u32>> = (0..4).map(|c| vec![c as u32]).collect();
        let sampler = ClusterSampler::new(clusters, 1);
        let e = ClusterSource::new_distributed(
            &ds,
            sampler,
            &spec,
            NormConfig::PAPER_DEFAULT,
            0,
            5,
        );
        assert!(e.is_err());
    }

    #[test]
    fn oversized_sampler_is_rejected() {
        let (ds, _) = source(1);
        let clusters = vec![(0..ds.n() as u32).collect::<Vec<_>>()];
        let sampler = ClusterSampler::new(clusters, 1);
        let spec = ModelSpec::gcn(ds.task, 2, ds.f_in, 16, ds.num_classes, 8);
        assert!(
            ClusterSource::new(&ds, sampler, &spec, NormConfig::PAPER_DEFAULT, 0).is_err()
        );
    }
}
