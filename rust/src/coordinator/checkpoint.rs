//! Checkpointing: persist/restore `TrainState` (weights + Adam moments
//! + step counter) so trained models survive the process — the paper's
//! workflow of "cluster once, train, reuse" extends to "train once,
//! evaluate anywhere" (CLI `train --save` / `eval`).
//!
//! Format: magic + version, artifact name, per-tensor (dims, f32 data),
//! little-endian.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::coordinator::trainer::TrainState;
use crate::runtime::Tensor;

const MAGIC: &[u8; 8] = b"CGCNCKP1";

fn w_u64(w: &mut impl Write, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn w_tensor(w: &mut impl Write, t: &Tensor) -> std::io::Result<()> {
    w_u64(w, t.dims.len() as u64)?;
    for &d in &t.dims {
        w_u64(w, d as u64)?;
    }
    let mut buf = Vec::with_capacity(t.data.len() * 4);
    for &x in &t.data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)
}

fn r_tensor(r: &mut impl Read) -> std::io::Result<Tensor> {
    let rank = r_u64(r)? as usize;
    if rank > 8 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "implausible tensor rank",
        ));
    }
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        dims.push(r_u64(r)? as usize);
    }
    let len: usize = dims.iter().product();
    let mut buf = vec![0u8; len * 4];
    r.read_exact(&mut buf)?;
    let data = buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Tensor::new(dims, data))
}

pub fn save(state: &TrainState, artifact: &str, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w_u64(&mut w, artifact.len() as u64)?;
    w.write_all(artifact.as_bytes())?;
    w_u64(&mut w, state.step)?;
    w_u64(&mut w, state.weights.len() as u64)?;
    for group in [&state.weights, &state.m, &state.v] {
        for t in group {
            w_tensor(&mut w, t)?;
        }
    }
    w.flush()
}

/// Returns (state, artifact name recorded at save time).
pub fn load(path: &Path) -> std::io::Result<(TrainState, String)> {
    let bad = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m);
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("not a cluster-gcn checkpoint"));
    }
    let name_len = r_u64(&mut r)? as usize;
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let artifact = String::from_utf8(name).map_err(|_| bad("bad name"))?;
    let step = r_u64(&mut r)?;
    let layers = r_u64(&mut r)? as usize;
    let mut groups: Vec<Vec<Tensor>> = Vec::with_capacity(3);
    for _ in 0..3 {
        let mut g = Vec::with_capacity(layers);
        for _ in 0..layers {
            g.push(r_tensor(&mut r)?);
        }
        groups.push(g);
    }
    let v = groups.pop().unwrap();
    let m = groups.pop().unwrap();
    let weights = groups.pop().unwrap();
    // invariants
    for (w_, m_) in weights.iter().zip(&m) {
        if w_.dims != m_.dims {
            return Err(bad("weight/moment shape mismatch"));
        }
    }
    Ok((TrainState { weights, m, v, step }, artifact))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Task;
    use crate::runtime::ModelSpec;

    fn state() -> TrainState {
        let spec = ModelSpec::gcn(Task::Multiclass, 3, 6, 10, 4, 128);
        let mut s = TrainState::init(&spec, 9);
        s.step = 77;
        s
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cgcn_ckpt_{}_{}", std::process::id(), tag));
        p
    }

    #[test]
    fn roundtrip() {
        let s = state();
        let p = tmp("rt");
        save(&s, "ppi_L3", &p).unwrap();
        let (s2, art) = load(&p).unwrap();
        assert_eq!(art, "ppi_L3");
        assert_eq!(s2.step, 77);
        assert_eq!(s2.weights.len(), 3);
        for (a, b) in s.weights.iter().zip(&s2.weights) {
            assert_eq!(a, b);
        }
        for (a, b) in s.v.iter().zip(&s2.v) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("bad");
        std::fs::write(&p, b"definitely not a checkpoint").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_truncated() {
        let s = state();
        let p = tmp("trunc");
        save(&s, "a", &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
