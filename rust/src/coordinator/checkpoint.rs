//! Checkpointing: persist/restore `TrainState` (weights + Adam moments
//! + step counter) so trained models survive the process — the paper's
//! workflow of "cluster once, train, reuse" extends to "train once,
//! evaluate anywhere" (CLI `train --save` / `eval` / `train --resume`).
//!
//! Three on-disk versions, all little-endian:
//!
//! | magic      | layout                                                        |
//! |------------|---------------------------------------------------------------|
//! | `CGCNCKP1` | name, step, per-tensor (dims, f32 data) × 3L                  |
//! | `CGCNCKP2` | the v1 body, then `epoch`, then a VR-GCN history section      |
//! | `CGCNCKP3` | the v2 layout, then a CRC32 trailer over every prior byte     |
//!
//! The v2 trailer is `epoch u64`, `hist_layers u64`, `n u64`,
//! `f_hid u64`, then `hist_layers` raw `n·f_hid` f32 blocks — the
//! historical-activation store VR-GCN's control-variate estimator lives
//! on.  Saving it is what makes `Session::initial_state` +
//! `TrainConfig::start_epoch` (+ `Session::initial_history`) replay an
//! interrupted VR-GCN run **bit-exactly**; v1/v2 files keep loading
//! unchanged.  v3 appends `crc u64` (IEEE CRC32 of every byte before
//! the trailer, zero-extended), so a torn or bit-flipped file is
//! detected at load time instead of silently resuming garbage.
//!
//! **Durability:** every save goes through [`atomic_write`] — the bytes
//! land in `<path>.tmp`, are fsynced, and only then renamed over the
//! destination — so a crash mid-save can never corrupt the previous
//! good checkpoint (the file `--resume` depends on).  On top of that,
//! [`RotatingCheckpoint`] keeps the last k epoch-stamped copies and
//! [`RotatingCheckpoint::load_latest`] falls back to the newest file
//! that still verifies, which is what the self-healing
//! [`crate::session::guard`] rolls back to.
//!
//! Errors are typed ([`CheckpointError`]): a cut v2/v3 trailer fails
//! with [`CheckpointError::TruncatedHistory`], a checksum mismatch with
//! [`CheckpointError::ChecksumMismatch`], and the failpoint sites
//! `ckpt.write` / `ckpt.torn` (see [`crate::util::failpoint`]) surface
//! as [`CheckpointError::Injected`] so chaos tests can distinguish
//! injected faults from real ones.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::coordinator::trainer::TrainState;
use crate::runtime::Tensor;
use crate::util::failpoint;

const MAGIC_V1: &[u8; 8] = b"CGCNCKP1";
const MAGIC_V2: &[u8; 8] = b"CGCNCKP2";
const MAGIC_V3: &[u8; 8] = b"CGCNCKP3";
/// Sanity cap on the history layer count (a real model has `L - 1`).
const MAX_HISTORY_LAYERS: u64 = 64;

/// Typed checkpoint failure.
#[derive(Debug)]
pub enum CheckpointError {
    /// Underlying file IO failed (open/read/write/flush/rename).
    Io(std::io::Error),
    /// The file is not a recognizable checkpoint, or its structural
    /// invariants do not hold.
    Corrupt(&'static str),
    /// A `CGCNCKP2`/`CGCNCKP3` trailer (epoch + history section) is cut
    /// short — the store the VR-GCN estimator depends on is incomplete,
    /// so the file must not be resumed from.
    TruncatedHistory,
    /// A `CGCNCKP3` CRC trailer does not match the payload — the file
    /// was torn or bit-flipped after (or during) the write.
    ChecksumMismatch,
    /// A failpoint fired inside checkpoint IO (chaos testing only;
    /// never produced on a real fault).
    Injected(crate::util::InjectedFault),
    /// No intact file remained after scanning a rotation set; carries
    /// how many candidates were tried and rejected.
    NoIntactCheckpoint {
        /// Number of candidate files that failed verification.
        tried: usize,
    },
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint io error: {e}"),
            CheckpointError::Corrupt(m) => write!(f, "corrupt checkpoint: {m}"),
            CheckpointError::TruncatedHistory => {
                write!(f, "checkpoint history section is truncated")
            }
            CheckpointError::ChecksumMismatch => {
                write!(f, "checkpoint checksum mismatch (torn or bit-flipped file)")
            }
            CheckpointError::Injected(fp) => write!(f, "checkpoint fault: {fp}"),
            CheckpointError::NoIntactCheckpoint { tried } => {
                write!(f, "no intact checkpoint found ({tried} candidates rejected)")
            }
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            CheckpointError::Injected(fp) => Some(fp),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> CheckpointError {
        CheckpointError::Io(e)
    }
}

type Result<T> = std::result::Result<T, CheckpointError>;

/// Serialized VR-GCN historical-activation store (layers `1..L-1`;
/// layer 0 is the exact feature matrix and is never stored).
#[derive(Clone, Debug, PartialEq)]
pub struct HistorySection {
    /// Hidden width of every stored layer.
    pub f_hid: usize,
    /// Nodes per layer.
    pub n: usize,
    /// `[layer][node * f_hid + j]`, each `n * f_hid` long.
    pub layers: Vec<Vec<f32>>,
}

/// A fully parsed checkpoint file (any version).
pub struct Checkpoint {
    /// Restored training state.
    pub state: TrainState,
    /// Model/artifact id recorded at save time.
    pub artifact: String,
    /// Epoch the state was saved at (v2/v3; `0` for v1 files, which do
    /// not record it).
    pub epoch: usize,
    /// VR-GCN history store (v2/v3 with a non-empty section; `None`
    /// otherwise).
    pub history: Option<HistorySection>,
}

// ---------------------------------------------------------------------
// CRC32 (IEEE), table-driven, streamed through reads/writes
// ---------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// Fold `bytes` into a running (finalized-form) CRC32; start from 0.
fn crc32_update(crc: u32, bytes: &[u8]) -> u32 {
    let mut c = !crc;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Reader adapter tallying the CRC of every byte it passes through.
struct CrcReader<R> {
    inner: R,
    crc: u32,
}

impl<R: Read> Read for CrcReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.crc = crc32_update(self.crc, &buf[..n]);
        Ok(n)
    }
}

/// Writer adapter tallying the CRC of every byte it passes through.
struct CrcWriter<W> {
    inner: W,
    crc: u32,
}

impl<W: Write> Write for CrcWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.crc = crc32_update(self.crc, &buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

// ---------------------------------------------------------------------
// primitive (de)serializers
// ---------------------------------------------------------------------

fn w_u64(w: &mut impl Write, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn w_f32s(w: &mut impl Write, xs: &[f32]) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)
}

fn r_f32s(r: &mut impl Read, len: usize) -> std::io::Result<Vec<f32>> {
    let mut buf = vec![0u8; len * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn w_tensor(w: &mut impl Write, t: &Tensor) -> std::io::Result<()> {
    w_u64(w, t.dims.len() as u64)?;
    for &d in &t.dims {
        w_u64(w, d as u64)?;
    }
    w_f32s(w, &t.data)
}

fn r_tensor(r: &mut impl Read) -> Result<Tensor> {
    let rank = r_u64(r)? as usize;
    if rank > 8 {
        return Err(CheckpointError::Corrupt("implausible tensor rank"));
    }
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        dims.push(r_u64(r)? as usize);
    }
    let len: usize = dims.iter().product();
    Ok(Tensor::new(dims, r_f32s(r, len)?))
}

/// The version-shared body: name, step, 3L tensors.
fn w_body(w: &mut impl Write, state: &TrainState, artifact: &str) -> std::io::Result<()> {
    w_u64(w, artifact.len() as u64)?;
    w.write_all(artifact.as_bytes())?;
    w_u64(w, state.step)?;
    w_u64(w, state.weights.len() as u64)?;
    for group in [&state.weights, &state.m, &state.v] {
        for t in group {
            w_tensor(w, t)?;
        }
    }
    Ok(())
}

fn r_body(r: &mut impl Read) -> Result<(TrainState, String)> {
    let name_len = r_u64(r)? as usize;
    if name_len > 4096 {
        return Err(CheckpointError::Corrupt("implausible name length"));
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let artifact = String::from_utf8(name)
        .map_err(|_| CheckpointError::Corrupt("artifact name is not utf-8"))?;
    let step = r_u64(r)?;
    let layers = r_u64(r)? as usize;
    let mut groups: Vec<Vec<Tensor>> = Vec::with_capacity(3);
    for _ in 0..3 {
        let mut g = Vec::with_capacity(layers);
        for _ in 0..layers {
            g.push(r_tensor(r)?);
        }
        groups.push(g);
    }
    let v = groups.pop().unwrap();
    let m = groups.pop().unwrap();
    let weights = groups.pop().unwrap();
    // invariants
    for (w_, m_) in weights.iter().zip(&m) {
        if w_.dims != m_.dims {
            return Err(CheckpointError::Corrupt("weight/moment shape mismatch"));
        }
    }
    Ok((TrainState { weights, m, v, step }, artifact))
}

/// The v2/v3 trailer body: epoch + history section.
fn w_trailer(
    w: &mut impl Write,
    epoch: usize,
    history: Option<&HistorySection>,
) -> Result<()> {
    w_u64(w, epoch as u64)?;
    match history {
        Some(h) => {
            for layer in &h.layers {
                if layer.len() != h.n * h.f_hid {
                    return Err(CheckpointError::Corrupt(
                        "history layer length != n * f_hid",
                    ));
                }
            }
            w_u64(w, h.layers.len() as u64)?;
            w_u64(w, h.n as u64)?;
            w_u64(w, h.f_hid as u64)?;
            for layer in &h.layers {
                w_f32s(w, layer)?;
            }
        }
        None => {
            w_u64(w, 0)?;
            w_u64(w, 0)?;
            w_u64(w, 0)?;
        }
    }
    Ok(())
}

/// Map an EOF inside the v2/v3 trailer to the typed truncation error.
fn truncated(e: std::io::Error) -> CheckpointError {
    if e.kind() == std::io::ErrorKind::UnexpectedEof {
        CheckpointError::TruncatedHistory
    } else {
        CheckpointError::Io(e)
    }
}

fn r_trailer(r: &mut impl Read) -> Result<(usize, Option<HistorySection>)> {
    let epoch = r_u64(r).map_err(truncated)? as usize;
    let hist_layers = r_u64(r).map_err(truncated)?;
    let n = r_u64(r).map_err(truncated)? as usize;
    let f_hid = r_u64(r).map_err(truncated)? as usize;
    if hist_layers > MAX_HISTORY_LAYERS {
        return Err(CheckpointError::Corrupt("implausible history layer count"));
    }
    let history = if hist_layers == 0 {
        None
    } else {
        let len = n
            .checked_mul(f_hid)
            .filter(|&l| l.checked_mul(4).is_some())
            .ok_or(CheckpointError::Corrupt("history dims overflow"))?;
        let mut layers = Vec::with_capacity(hist_layers as usize);
        for _ in 0..hist_layers {
            layers.push(r_f32s(r, len).map_err(truncated)?);
        }
        Some(HistorySection { f_hid, n, layers })
    };
    Ok((epoch, history))
}

// ---------------------------------------------------------------------
// atomic writes
// ---------------------------------------------------------------------

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|s| s.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Crash-durable write: the body lands in `<path>.tmp`, is fsynced,
/// and only then renamed over `path` — so at every instant `path`
/// holds either the previous complete file or the new complete file,
/// never a torn mix.  Failpoints: `ckpt.write` fails before the tmp is
/// opened; the body may inject its own mid-write faults (`ckpt.torn`),
/// in which case the torn tmp is deliberately left behind (it is what
/// a crash would leave) and `path` stays untouched.
fn atomic_write<F>(path: &Path, write_body: F) -> Result<()>
where
    F: FnOnce(&mut dyn Write) -> Result<()>,
{
    failpoint::check("ckpt.write").map_err(CheckpointError::Injected)?;
    let tmp = tmp_path(path);
    let file = File::create(&tmp)?;
    let mut w = BufWriter::new(file);
    let res = (|| -> Result<()> {
        write_body(&mut w)?;
        w.flush()?;
        w.get_ref().sync_all()?;
        Ok(())
    })();
    if let Err(e) = res {
        // an injected torn write simulates a crash: leave the torn tmp
        // on disk exactly as a crash would; real IO errors clean up
        if !matches!(e, CheckpointError::Injected(_)) {
            let _ = std::fs::remove_file(&tmp);
        }
        return Err(e);
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

// ---------------------------------------------------------------------
// save / load
// ---------------------------------------------------------------------

/// Write a `CGCNCKP1` checkpoint (no epoch, no history) — the format
/// every pre-v2 file uses.  Atomic (tmp + fsync + rename).
pub fn save(state: &TrainState, artifact: &str, path: &Path) -> Result<()> {
    atomic_write(path, |w| {
        w.write_all(MAGIC_V1)?;
        w_body(w, state, artifact)?;
        Ok(())
    })
}

/// Write a `CGCNCKP2` checkpoint: the v1 body plus the saved-at epoch
/// and (for VR-GCN runs) the historical-activation store.  Atomic.
pub fn save_v2(
    state: &TrainState,
    artifact: &str,
    epoch: usize,
    history: Option<&HistorySection>,
    path: &Path,
) -> Result<()> {
    atomic_write(path, |w| {
        w.write_all(MAGIC_V2)?;
        w_body(w, state, artifact)?;
        w_trailer(w, epoch, history)
    })
}

/// Write a `CGCNCKP3` checkpoint: the v2 layout plus a CRC32 trailer
/// over every preceding byte, so torn/bit-flipped files are detected at
/// load time.  Atomic.  The `ckpt.torn` failpoint cuts the write after
/// the body (simulating a crash mid-save); the destination file is
/// never touched in that case.
pub fn save_v3(
    state: &TrainState,
    artifact: &str,
    epoch: usize,
    history: Option<&HistorySection>,
    path: &Path,
) -> Result<()> {
    atomic_write(path, |w| {
        let mut cw = CrcWriter { inner: w, crc: 0 };
        cw.write_all(MAGIC_V3)?;
        w_body(&mut cw, state, artifact)?;
        failpoint::check("ckpt.torn").map_err(CheckpointError::Injected)?;
        w_trailer(&mut cw, epoch, history)?;
        let crc = cw.crc;
        w_u64(&mut cw.inner, crc as u64)?;
        Ok(())
    })
}

/// Load any checkpoint version in full; v3 files are CRC-verified.
pub fn load_full(path: &Path) -> Result<Checkpoint> {
    let mut r = CrcReader { inner: BufReader::new(File::open(path)?), crc: 0 };
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let version = match &magic {
        m if m == MAGIC_V1 => 1,
        m if m == MAGIC_V2 => 2,
        m if m == MAGIC_V3 => 3,
        _ => return Err(CheckpointError::Corrupt("not a cluster-gcn checkpoint")),
    };
    let (state, artifact) = r_body(&mut r)?;
    if version == 1 {
        return Ok(Checkpoint { state, artifact, epoch: 0, history: None });
    }
    let (epoch, history) = r_trailer(&mut r)?;
    if version == 3 {
        let want = r.crc as u64;
        let got = r_u64(&mut r).map_err(truncated)?;
        if got != want {
            return Err(CheckpointError::ChecksumMismatch);
        }
    }
    Ok(Checkpoint { state, artifact, epoch, history })
}

/// Returns (state, artifact name recorded at save time) — the
/// compatibility surface; reads every version and drops the trailer.
pub fn load(path: &Path) -> Result<(TrainState, String)> {
    let ck = load_full(path)?;
    Ok((ck.state, ck.artifact))
}

/// Load `path`, and when it is torn/corrupt/missing, fall back to the
/// newest intact epoch-stamped sibling — first `<path>.e<epoch>` (the
/// plain [`RotatingCheckpoint`] layout), then `<path>.guard.e<epoch>`
/// (the rotation a `--guard` run keeps beside its `--save` target).
/// Returns the checkpoint plus the file it actually came from.  The
/// original error is preserved when no fallback candidate verifies
/// either.
pub fn load_full_or_fallback(path: &Path) -> Result<(Checkpoint, PathBuf)> {
    let primary = match load_full(path) {
        Ok(ck) => return Ok((ck, path.to_path_buf())),
        Err(e) => e,
    };
    let mut guard_name = path.file_name().map(|s| s.to_os_string()).unwrap_or_default();
    guard_name.push(".guard");
    for base in [path.to_path_buf(), path.with_file_name(guard_name)] {
        let store = RotatingCheckpoint::new(base, usize::MAX);
        if let Ok((ck, from, _skipped)) = store.load_latest() {
            return Ok((ck, from));
        }
    }
    Err(primary)
}

// ---------------------------------------------------------------------
// keep-last-k rotation
// ---------------------------------------------------------------------

/// Keep-last-k checkpoint rotation over epoch-stamped `CGCNCKP3` files:
/// [`RotatingCheckpoint::save`] writes `<base>.e<epoch>` atomically and
/// prunes everything but the newest `keep` epochs;
/// [`RotatingCheckpoint::load_latest`] walks the set newest-first and
/// returns the first file that fully verifies — the rollback target the
/// self-healing trainer ([`crate::session::guard`]) depends on when the
/// newest save was torn by a crash.
pub struct RotatingCheckpoint {
    base: PathBuf,
    keep: usize,
}

impl RotatingCheckpoint {
    /// A rotation set rooted at `base` keeping the newest `keep` (≥ 1)
    /// epochs.  `base` itself is never written; slots live beside it as
    /// `<base>.e<epoch>`.
    pub fn new(base: impl Into<PathBuf>, keep: usize) -> RotatingCheckpoint {
        RotatingCheckpoint { base: base.into(), keep: keep.max(1) }
    }

    /// The slot path for `epoch`.
    pub fn slot(&self, epoch: usize) -> PathBuf {
        let mut name = self
            .base
            .file_name()
            .map(|s| s.to_os_string())
            .unwrap_or_default();
        name.push(format!(".e{epoch}"));
        self.base.with_file_name(name)
    }

    /// Epoch-stamped slots currently on disk, ascending by epoch.
    pub fn list(&self) -> Result<Vec<(usize, PathBuf)>> {
        let dir = match self.base.parent() {
            Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
            _ => PathBuf::from("."),
        };
        let stem = match self.base.file_name().and_then(|s| s.to_str()) {
            Some(s) => format!("{s}.e"),
            None => return Ok(Vec::new()),
        };
        let mut slots = Vec::new();
        let entries = match std::fs::read_dir(&dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(e.into()),
        };
        for entry in entries {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(suffix) = name.strip_prefix(&stem) else { continue };
            let Ok(epoch) = suffix.parse::<usize>() else { continue };
            slots.push((epoch, entry.path()));
        }
        slots.sort_unstable_by_key(|&(e, _)| e);
        Ok(slots)
    }

    /// Save a v3 checkpoint into the `epoch` slot (atomic), then prune
    /// slots beyond the newest `keep`.  Returns the slot path written.
    pub fn save(
        &self,
        state: &TrainState,
        artifact: &str,
        epoch: usize,
        history: Option<&HistorySection>,
    ) -> Result<PathBuf> {
        if let Some(dir) = self.base.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let path = self.slot(epoch);
        save_v3(state, artifact, epoch, history, &path)?;
        let slots = self.list()?;
        if slots.len() > self.keep {
            for (_, old) in &slots[..slots.len() - self.keep] {
                let _ = std::fs::remove_file(old);
            }
        }
        Ok(path)
    }

    /// Newest slot that fully verifies, walking newest-first past any
    /// torn/corrupt/unreadable file.  Returns the checkpoint, the file
    /// it came from, and how many newer candidates were rejected.
    /// [`CheckpointError::NoIntactCheckpoint`] when nothing verifies.
    pub fn load_latest(&self) -> Result<(Checkpoint, PathBuf, usize)> {
        let slots = self.list()?;
        let mut rejected = 0usize;
        for (_, path) in slots.iter().rev() {
            match load_full(path) {
                Ok(ck) => return Ok((ck, path.clone(), rejected)),
                Err(_) => rejected += 1,
            }
        }
        Err(CheckpointError::NoIntactCheckpoint { tried: rejected })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Task;
    use crate::runtime::ModelSpec;

    fn state() -> TrainState {
        let spec = ModelSpec::gcn(Task::Multiclass, 3, 6, 10, 4, 128);
        let mut s = TrainState::init(&spec, 9);
        s.step = 77;
        s
    }

    fn history() -> HistorySection {
        HistorySection {
            f_hid: 3,
            n: 5,
            layers: vec![
                (0..15).map(|i| i as f32 * 0.5).collect(),
                (0..15).map(|i| -(i as f32)).collect(),
            ],
        }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cgcn_ckpt_{}_{}", std::process::id(), tag));
        p
    }

    #[test]
    fn roundtrip() {
        let s = state();
        let p = tmp("rt");
        save(&s, "ppi_L3", &p).unwrap();
        let (s2, art) = load(&p).unwrap();
        assert_eq!(art, "ppi_L3");
        assert_eq!(s2.step, 77);
        assert_eq!(s2.weights.len(), 3);
        for (a, b) in s.weights.iter().zip(&s2.weights) {
            assert_eq!(a, b);
        }
        for (a, b) in s.v.iter().zip(&s2.v) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v2_roundtrips_epoch_and_history() {
        let s = state();
        let h = history();
        let p = tmp("v2");
        save_v2(&s, "ppi_vrgcn_L3", 17, Some(&h), &p).unwrap();
        let ck = load_full(&p).unwrap();
        assert_eq!(ck.artifact, "ppi_vrgcn_L3");
        assert_eq!(ck.epoch, 17);
        assert_eq!(ck.history.as_ref(), Some(&h));
        // the compat loader reads the same file
        let (s2, art) = load(&p).unwrap();
        assert_eq!(art, "ppi_vrgcn_L3");
        assert_eq!(s2.step, s.step);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v2_without_history_loads_none() {
        let s = state();
        let p = tmp("v2n");
        save_v2(&s, "cora_L2", 3, None, &p).unwrap();
        let ck = load_full(&p).unwrap();
        assert_eq!(ck.epoch, 3);
        assert!(ck.history.is_none());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v3_roundtrips_and_is_bytewise_stable() {
        let s = state();
        let h = history();
        let p = tmp("v3");
        save_v3(&s, "m3", 9, Some(&h), &p).unwrap();
        let ck = load_full(&p).unwrap();
        assert_eq!(ck.artifact, "m3");
        assert_eq!(ck.epoch, 9);
        assert_eq!(ck.history.as_ref(), Some(&h));
        // save → load → save is bytewise stable (same contract v1/v2 pin)
        let bytes1 = std::fs::read(&p).unwrap();
        let p2 = tmp("v3b");
        save_v3(&ck.state, &ck.artifact, ck.epoch, ck.history.as_ref(), &p2).unwrap();
        assert_eq!(bytes1, std::fs::read(&p2).unwrap());
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn v3_detects_bitflips_anywhere() {
        let s = state();
        let p = tmp("v3flip");
        save_v3(&s, "m", 2, Some(&history()), &p).unwrap();
        let clean = std::fs::read(&p).unwrap();
        // flip one bit in the payload, in the history, and in the CRC
        for pos in [64usize, clean.len() - 20, clean.len() - 3] {
            let mut bytes = clean.clone();
            bytes[pos] ^= 0x10;
            std::fs::write(&p, &bytes).unwrap();
            match load_full(&p) {
                Err(CheckpointError::ChecksumMismatch)
                | Err(CheckpointError::Corrupt(_)) => {}
                other => panic!(
                    "flip at {pos}: expected checksum/corrupt error, got {:?}",
                    other.err().map(|e| e.to_string())
                ),
            }
        }
        std::fs::write(&p, &clean).unwrap();
        assert!(load_full(&p).is_ok(), "unflipped file must still verify");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn atomic_save_never_tears_the_previous_file() {
        let s = state();
        let p = tmp("atomic");
        save_v3(&s, "gen1", 1, None, &p).unwrap();
        // a failed overwrite must leave gen1 fully intact
        let before = std::fs::read(&p).unwrap();
        // simulate failure by writing a tmp and never renaming — the
        // real crash window; the destination is untouched by contract
        std::fs::write(tmp_path(&p), b"torn garbage").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), before);
        let ck = load_full(&p).unwrap();
        assert_eq!(ck.artifact, "gen1");
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(tmp_path(&p)).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("bad");
        std::fs::write(&p, b"definitely not a checkpoint").unwrap();
        assert!(matches!(load(&p), Err(CheckpointError::Corrupt(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_truncated() {
        let s = state();
        let p = tmp("trunc");
        save(&s, "a", &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    /// The typed error contract: cutting a v2 file anywhere inside its
    /// trailer (epoch, history header, or history payload) is reported
    /// as `TruncatedHistory`, not a generic IO error.
    #[test]
    fn truncated_history_is_typed() {
        let s = state();
        let h = history();
        let p = tmp("trunc_hist");
        save_v2(&s, "m", 5, Some(&h), &p).unwrap();
        let full = std::fs::read(&p).unwrap();
        let trailer = 8 * 4 + h.layers.len() * h.n * h.f_hid * 4;
        for cut in [1usize, 7, 13, trailer - 1] {
            std::fs::write(&p, &full[..full.len() - cut]).unwrap();
            match load_full(&p) {
                Err(CheckpointError::TruncatedHistory) => {}
                other => panic!(
                    "cut {cut}: expected TruncatedHistory, got {:?}",
                    other.err().map(|e| e.to_string())
                ),
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rotation_keeps_last_k_and_falls_back_past_corruption() {
        let s = state();
        let base = tmp("rot");
        let store = RotatingCheckpoint::new(&base, 3);
        for epoch in 1..=5 {
            store.save(&s, "rotm", epoch, None).unwrap();
        }
        let slots = store.list().unwrap();
        let epochs: Vec<usize> = slots.iter().map(|&(e, _)| e).collect();
        assert_eq!(epochs, vec![3, 4, 5], "keep-last-3 prunes epochs 1 and 2");

        // intact set loads the newest
        let (ck, from, rejected) = store.load_latest().unwrap();
        assert_eq!((ck.epoch, rejected), (5, 0));
        assert_eq!(from, store.slot(5));

        // tear the newest (truncate) and bit-flip the next: fallback
        // walks to epoch 3, reporting both rejections
        let newest = std::fs::read(store.slot(5)).unwrap();
        std::fs::write(store.slot(5), &newest[..newest.len() / 3]).unwrap();
        let mut mid = std::fs::read(store.slot(4)).unwrap();
        let flip = mid.len() / 2;
        mid[flip] ^= 0x40;
        std::fs::write(store.slot(4), &mid).unwrap();
        let (ck, from, rejected) = store.load_latest().unwrap();
        assert_eq!((ck.epoch, rejected), (3, 2));
        assert_eq!(from, store.slot(3));

        // everything corrupt → typed NoIntactCheckpoint
        let third = std::fs::read(store.slot(3)).unwrap();
        std::fs::write(store.slot(3), &third[..10]).unwrap();
        assert!(matches!(
            store.load_latest(),
            Err(CheckpointError::NoIntactCheckpoint { tried: 3 })
        ));

        // load_full_or_fallback: primary missing, siblings scanned
        std::fs::write(store.slot(3), &third).unwrap();
        let (ck, from) = load_full_or_fallback(&base).unwrap();
        assert_eq!(ck.epoch, 3);
        assert_eq!(from, store.slot(3));

        for (_, p) in store.list().unwrap() {
            std::fs::remove_file(p).ok();
        }
    }
}
