//! Checkpointing: persist/restore `TrainState` (weights + Adam moments
//! + step counter) so trained models survive the process — the paper's
//! workflow of "cluster once, train, reuse" extends to "train once,
//! evaluate anywhere" (CLI `train --save` / `eval` / `train --resume`).
//!
//! Two on-disk versions, both little-endian:
//!
//! | magic      | layout                                                        |
//! |------------|---------------------------------------------------------------|
//! | `CGCNCKP1` | name, step, per-tensor (dims, f32 data) × 3L                  |
//! | `CGCNCKP2` | the v1 body, then `epoch`, then a VR-GCN history section      |
//!
//! The v2 trailer is `epoch u64`, `hist_layers u64`, `n u64`,
//! `f_hid u64`, then `hist_layers` raw `n·f_hid` f32 blocks — the
//! historical-activation store VR-GCN's control-variate estimator lives
//! on.  Saving it is what makes `Session::initial_state` +
//! `TrainConfig::start_epoch` (+ `Session::initial_history`) replay an
//! interrupted VR-GCN run **bit-exactly**; v1 files keep loading
//! unchanged.  Errors are typed ([`CheckpointError`]): a v2 file whose
//! history section is cut short fails with
//! [`CheckpointError::TruncatedHistory`], not a generic IO error.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::coordinator::trainer::TrainState;
use crate::runtime::Tensor;

const MAGIC_V1: &[u8; 8] = b"CGCNCKP1";
const MAGIC_V2: &[u8; 8] = b"CGCNCKP2";
/// Sanity cap on the history layer count (a real model has `L - 1`).
const MAX_HISTORY_LAYERS: u64 = 64;

/// Typed checkpoint failure.
#[derive(Debug)]
pub enum CheckpointError {
    /// Underlying file IO failed (open/read/write/flush).
    Io(std::io::Error),
    /// The file is not a recognizable checkpoint, or its structural
    /// invariants do not hold.
    Corrupt(&'static str),
    /// A `CGCNCKP2` trailer (epoch + history section) is cut short —
    /// the store the VR-GCN estimator depends on is incomplete, so the
    /// file must not be resumed from.
    TruncatedHistory,
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint io error: {e}"),
            CheckpointError::Corrupt(m) => write!(f, "corrupt checkpoint: {m}"),
            CheckpointError::TruncatedHistory => {
                write!(f, "checkpoint history section is truncated")
            }
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> CheckpointError {
        CheckpointError::Io(e)
    }
}

type Result<T> = std::result::Result<T, CheckpointError>;

/// Serialized VR-GCN historical-activation store (layers `1..L-1`;
/// layer 0 is the exact feature matrix and is never stored).
#[derive(Clone, Debug, PartialEq)]
pub struct HistorySection {
    /// Hidden width of every stored layer.
    pub f_hid: usize,
    /// Nodes per layer.
    pub n: usize,
    /// `[layer][node * f_hid + j]`, each `n * f_hid` long.
    pub layers: Vec<Vec<f32>>,
}

/// A fully parsed checkpoint file (either version).
pub struct Checkpoint {
    /// Restored training state.
    pub state: TrainState,
    /// Model/artifact id recorded at save time.
    pub artifact: String,
    /// Epoch the state was saved at (v2; `0` for v1 files, which do not
    /// record it).
    pub epoch: usize,
    /// VR-GCN history store (v2 with a non-empty section; `None`
    /// otherwise).
    pub history: Option<HistorySection>,
}

fn w_u64(w: &mut impl Write, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn w_f32s(w: &mut impl Write, xs: &[f32]) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)
}

fn r_f32s(r: &mut impl Read, len: usize) -> std::io::Result<Vec<f32>> {
    let mut buf = vec![0u8; len * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn w_tensor(w: &mut impl Write, t: &Tensor) -> std::io::Result<()> {
    w_u64(w, t.dims.len() as u64)?;
    for &d in &t.dims {
        w_u64(w, d as u64)?;
    }
    w_f32s(w, &t.data)
}

fn r_tensor(r: &mut impl Read) -> Result<Tensor> {
    let rank = r_u64(r)? as usize;
    if rank > 8 {
        return Err(CheckpointError::Corrupt("implausible tensor rank"));
    }
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        dims.push(r_u64(r)? as usize);
    }
    let len: usize = dims.iter().product();
    Ok(Tensor::new(dims, r_f32s(r, len)?))
}

/// The version-shared body: name, step, 3L tensors.
fn w_body(w: &mut impl Write, state: &TrainState, artifact: &str) -> std::io::Result<()> {
    w_u64(w, artifact.len() as u64)?;
    w.write_all(artifact.as_bytes())?;
    w_u64(w, state.step)?;
    w_u64(w, state.weights.len() as u64)?;
    for group in [&state.weights, &state.m, &state.v] {
        for t in group {
            w_tensor(w, t)?;
        }
    }
    Ok(())
}

fn r_body(r: &mut impl Read) -> Result<(TrainState, String)> {
    let name_len = r_u64(r)? as usize;
    if name_len > 4096 {
        return Err(CheckpointError::Corrupt("implausible name length"));
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let artifact = String::from_utf8(name)
        .map_err(|_| CheckpointError::Corrupt("artifact name is not utf-8"))?;
    let step = r_u64(r)?;
    let layers = r_u64(r)? as usize;
    let mut groups: Vec<Vec<Tensor>> = Vec::with_capacity(3);
    for _ in 0..3 {
        let mut g = Vec::with_capacity(layers);
        for _ in 0..layers {
            g.push(r_tensor(r)?);
        }
        groups.push(g);
    }
    let v = groups.pop().unwrap();
    let m = groups.pop().unwrap();
    let weights = groups.pop().unwrap();
    // invariants
    for (w_, m_) in weights.iter().zip(&m) {
        if w_.dims != m_.dims {
            return Err(CheckpointError::Corrupt("weight/moment shape mismatch"));
        }
    }
    Ok((TrainState { weights, m, v, step }, artifact))
}

/// Write a `CGCNCKP1` checkpoint (no epoch, no history) — the format
/// every pre-v2 file uses and non-VR-GCN runs keep writing.
pub fn save(state: &TrainState, artifact: &str, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC_V1)?;
    w_body(&mut w, state, artifact)?;
    w.flush()?;
    Ok(())
}

/// Write a `CGCNCKP2` checkpoint: the v1 body plus the saved-at epoch
/// and (for VR-GCN runs) the historical-activation store.
pub fn save_v2(
    state: &TrainState,
    artifact: &str,
    epoch: usize,
    history: Option<&HistorySection>,
    path: &Path,
) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC_V2)?;
    w_body(&mut w, state, artifact)?;
    w_u64(&mut w, epoch as u64)?;
    match history {
        Some(h) => {
            for layer in &h.layers {
                if layer.len() != h.n * h.f_hid {
                    return Err(CheckpointError::Corrupt(
                        "history layer length != n * f_hid",
                    ));
                }
            }
            w_u64(&mut w, h.layers.len() as u64)?;
            w_u64(&mut w, h.n as u64)?;
            w_u64(&mut w, h.f_hid as u64)?;
            for layer in &h.layers {
                w_f32s(&mut w, layer)?;
            }
        }
        None => {
            w_u64(&mut w, 0)?;
            w_u64(&mut w, 0)?;
            w_u64(&mut w, 0)?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Map an EOF inside the v2 trailer to the typed truncation error.
fn truncated(e: std::io::Error) -> CheckpointError {
    if e.kind() == std::io::ErrorKind::UnexpectedEof {
        CheckpointError::TruncatedHistory
    } else {
        CheckpointError::Io(e)
    }
}

/// Load either checkpoint version in full.
pub fn load_full(path: &Path) -> Result<Checkpoint> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let v2 = match &magic {
        m if m == MAGIC_V1 => false,
        m if m == MAGIC_V2 => true,
        _ => return Err(CheckpointError::Corrupt("not a cluster-gcn checkpoint")),
    };
    let (state, artifact) = r_body(&mut r)?;
    if !v2 {
        return Ok(Checkpoint { state, artifact, epoch: 0, history: None });
    }
    let epoch = r_u64(&mut r).map_err(truncated)? as usize;
    let hist_layers = r_u64(&mut r).map_err(truncated)?;
    let n = r_u64(&mut r).map_err(truncated)? as usize;
    let f_hid = r_u64(&mut r).map_err(truncated)? as usize;
    if hist_layers > MAX_HISTORY_LAYERS {
        return Err(CheckpointError::Corrupt("implausible history layer count"));
    }
    let history = if hist_layers == 0 {
        None
    } else {
        let len = n
            .checked_mul(f_hid)
            .filter(|&l| l.checked_mul(4).is_some())
            .ok_or(CheckpointError::Corrupt("history dims overflow"))?;
        let mut layers = Vec::with_capacity(hist_layers as usize);
        for _ in 0..hist_layers {
            layers.push(r_f32s(&mut r, len).map_err(truncated)?);
        }
        Some(HistorySection { f_hid, n, layers })
    };
    Ok(Checkpoint { state, artifact, epoch, history })
}

/// Returns (state, artifact name recorded at save time) — the
/// compatibility surface; reads both versions and drops the v2 trailer.
pub fn load(path: &Path) -> Result<(TrainState, String)> {
    let ck = load_full(path)?;
    Ok((ck.state, ck.artifact))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Task;
    use crate::runtime::ModelSpec;

    fn state() -> TrainState {
        let spec = ModelSpec::gcn(Task::Multiclass, 3, 6, 10, 4, 128);
        let mut s = TrainState::init(&spec, 9);
        s.step = 77;
        s
    }

    fn history() -> HistorySection {
        HistorySection {
            f_hid: 3,
            n: 5,
            layers: vec![
                (0..15).map(|i| i as f32 * 0.5).collect(),
                (0..15).map(|i| -(i as f32)).collect(),
            ],
        }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cgcn_ckpt_{}_{}", std::process::id(), tag));
        p
    }

    #[test]
    fn roundtrip() {
        let s = state();
        let p = tmp("rt");
        save(&s, "ppi_L3", &p).unwrap();
        let (s2, art) = load(&p).unwrap();
        assert_eq!(art, "ppi_L3");
        assert_eq!(s2.step, 77);
        assert_eq!(s2.weights.len(), 3);
        for (a, b) in s.weights.iter().zip(&s2.weights) {
            assert_eq!(a, b);
        }
        for (a, b) in s.v.iter().zip(&s2.v) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v2_roundtrips_epoch_and_history() {
        let s = state();
        let h = history();
        let p = tmp("v2");
        save_v2(&s, "ppi_vrgcn_L3", 17, Some(&h), &p).unwrap();
        let ck = load_full(&p).unwrap();
        assert_eq!(ck.artifact, "ppi_vrgcn_L3");
        assert_eq!(ck.epoch, 17);
        assert_eq!(ck.history.as_ref(), Some(&h));
        // the compat loader reads the same file
        let (s2, art) = load(&p).unwrap();
        assert_eq!(art, "ppi_vrgcn_L3");
        assert_eq!(s2.step, s.step);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v2_without_history_loads_none() {
        let s = state();
        let p = tmp("v2n");
        save_v2(&s, "cora_L2", 3, None, &p).unwrap();
        let ck = load_full(&p).unwrap();
        assert_eq!(ck.epoch, 3);
        assert!(ck.history.is_none());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("bad");
        std::fs::write(&p, b"definitely not a checkpoint").unwrap();
        assert!(matches!(load(&p), Err(CheckpointError::Corrupt(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_truncated() {
        let s = state();
        let p = tmp("trunc");
        save(&s, "a", &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    /// The typed error contract: cutting a v2 file anywhere inside its
    /// trailer (epoch, history header, or history payload) is reported
    /// as `TruncatedHistory`, not a generic IO error.
    #[test]
    fn truncated_history_is_typed() {
        let s = state();
        let h = history();
        let p = tmp("trunc_hist");
        save_v2(&s, "m", 5, Some(&h), &p).unwrap();
        let full = std::fs::read(&p).unwrap();
        let trailer = 8 * 4 + h.layers.len() * h.n * h.f_hid * 4;
        for cut in [1usize, 7, 13, trailer - 1] {
            std::fs::write(&p, &full[..full.len() - cut]).unwrap();
            match load_full(&p) {
                Err(CheckpointError::TruncatedHistory) => {}
                other => panic!(
                    "cut {cut}: expected TruncatedHistory, got {:?}",
                    other.err().map(|e| e.to_string())
                ),
            }
        }
        std::fs::remove_file(&p).ok();
    }
}
