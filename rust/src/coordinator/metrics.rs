//! Evaluation metrics: micro-F1 (the paper's accuracy metric for both
//! the multi-class and multi-label tasks) and label entropy (Fig. 2).
//!
//! Logits rows with no finite entry (a NaN-poisoned forward) are
//! scored as wrong — never as "predicted class 0" — and counted in the
//! process-wide [`non_finite_rows`] counter so the session guard can
//! tell a poisoned eval from a merely bad one.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::graph::{Dataset, Labels, Task};

/// Monotonic count of logits rows rejected because they held no finite
/// entry (all NaN / −inf).  See [`non_finite_rows`].
static NON_FINITE_ROWS: AtomicU64 = AtomicU64::new(0);

/// Total non-finite logits rows seen by evaluation since process start
/// (monotonic).  A caller that wants the per-eval count snapshots the
/// value before and after — the self-healing guard layer uses the delta
/// to distinguish a NaN-poisoned forward from a low score.
pub fn non_finite_rows() -> u64 {
    NON_FINITE_ROWS.load(Ordering::Relaxed)
}

/// Record one poisoned (no finite entry) logits row.  Internal to the
/// metric implementations here and in `coordinator::storage`.
pub(crate) fn note_non_finite_row() {
    NON_FINITE_ROWS.fetch_add(1, Ordering::Relaxed);
}

/// Micro-F1 over the given nodes from dense logits rows.
///
/// - multiclass: argmax prediction; micro-F1 == accuracy.
/// - multilabel: sigmoid(logit) > 0.5 ⇔ logit > 0 per class.
///
/// A row with no finite logit scores as wrong (and increments
/// [`non_finite_rows`]): multiclass skips it as incorrect instead of
/// letting a saturated argmax claim class 0, multilabel predicts every
/// class negative so each true label counts as a false negative.
pub fn micro_f1(
    ds: &Dataset,
    nodes: &[u32],
    logits: &[f32],
    classes: usize,
) -> f64 {
    debug_assert_eq!(logits.len(), nodes.len() * classes);
    match ds.task {
        Task::Multiclass => {
            let mut correct = 0usize;
            for (i, &v) in nodes.iter().enumerate() {
                let row = &logits[i * classes..(i + 1) * classes];
                let pred = match argmax_finite(row) {
                    Some(p) => p,
                    None => {
                        note_non_finite_row();
                        continue; // counts as wrong: total is nodes.len()
                    }
                };
                if ds.labels.has_label(v as usize, pred) {
                    correct += 1;
                }
            }
            if nodes.is_empty() {
                0.0
            } else {
                correct as f64 / nodes.len() as f64
            }
        }
        Task::Multilabel => {
            let (mut tp, mut fp, mut fnn) = (0u64, 0u64, 0u64);
            for (i, &v) in nodes.iter().enumerate() {
                let row = &logits[i * classes..(i + 1) * classes];
                if row.iter().all(|x| !x.is_finite()) {
                    note_non_finite_row();
                    // fall through: `NaN > 0.0` is false, so every class
                    // predicts negative and true labels become fn
                }
                for c in 0..classes {
                    let pred = row[c] > 0.0;
                    let truth = ds.labels.has_label(v as usize, c);
                    match (pred, truth) {
                        (true, true) => tp += 1,
                        (true, false) => fp += 1,
                        (false, true) => fnn += 1,
                        (false, false) => {}
                    }
                }
            }
            let denom = 2 * tp + fp + fnn;
            if denom == 0 {
                0.0
            } else {
                2.0 * tp as f64 / denom as f64
            }
        }
    }
}

/// Index of the largest *finite* entry, `None` when the row has none
/// (all NaN / −inf — e.g. a poisoned forward).  Non-finite entries are
/// skipped, so a partially poisoned row still predicts its best finite
/// class.
pub fn argmax_finite(row: &[f32]) -> Option<usize> {
    let mut best: Option<usize> = None;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v.is_finite() && (best.is_none() || v > bv) {
            bv = v;
            best = Some(i);
        }
    }
    best
}

/// [`argmax_finite`] with the historical index-0 fallback for rows
/// with no finite entry.  Metric code must not use this directly — a
/// fallback 0 silently scores a poisoned row as "predicted class 0";
/// use [`argmax_finite`] and count the `None` rows as wrong.
pub fn argmax(row: &[f32]) -> usize {
    argmax_finite(row).unwrap_or(0)
}

/// Label-distribution entropy of a batch (Fig. 2); multiclass uses the
/// class histogram, multilabel the per-class positive counts.
pub fn batch_label_entropy(ds: &Dataset, nodes: &[u32]) -> f64 {
    let hist = ds.label_histogram(nodes);
    crate::util::entropy(&hist)
}

/// Fraction of exactly-matching label sets (subset accuracy; secondary
/// metric for multilabel sanity checks).
pub fn subset_accuracy(
    ds: &Dataset,
    nodes: &[u32],
    logits: &[f32],
    classes: usize,
) -> f64 {
    if nodes.is_empty() {
        return 0.0;
    }
    let mut exact = 0usize;
    for (i, &v) in nodes.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let ok = match &ds.labels {
            Labels::Multiclass(l) => argmax_finite(row) == Some(l[v as usize] as usize),
            Labels::Multilabel { .. } => (0..classes)
                .all(|c| (row[c] > 0.0) == ds.labels.has_label(v as usize, c)),
        };
        if ok {
            exact += 1;
        }
    }
    exact as f64 / nodes.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Csr, Split};

    fn ds_multiclass() -> Dataset {
        Dataset {
            name: "m".into(),
            task: Task::Multiclass,
            graph: Csr::from_edges(3, &[(0, 1)]),
            f_in: 1,
            num_classes: 3,
            features: vec![0.0; 3],
            labels: Labels::Multiclass(vec![0, 1, 2]),
            split: vec![Split::Train; 3],
        }
    }

    #[test]
    fn multiclass_f1_is_accuracy() {
        let ds = ds_multiclass();
        // predictions: node0 -> 0 (right), node1 -> 2 (wrong), node2 -> 2
        let logits = vec![
            5.0, 0.0, 0.0, //
            0.0, 1.0, 3.0, //
            0.0, 0.0, 9.0,
        ];
        let f1 = micro_f1(&ds, &[0, 1, 2], &logits, 3);
        assert!((f1 - 2.0 / 3.0).abs() < 1e-12);
    }

    fn ds_multilabel() -> Dataset {
        let mut labels = Labels::multilabel_new(2, 3);
        labels.set_label(0, 0);
        labels.set_label(0, 1);
        labels.set_label(1, 2);
        Dataset {
            name: "ml".into(),
            task: Task::Multilabel,
            graph: Csr::from_edges(2, &[(0, 1)]),
            f_in: 1,
            num_classes: 3,
            features: vec![0.0; 2],
            labels,
            split: vec![Split::Train; 2],
        }
    }

    #[test]
    fn multilabel_f1() {
        let ds = ds_multilabel();
        // node0 predicts {0} (1 tp, 1 fn); node1 predicts {1,2} (1 tp, 1 fp)
        let logits = vec![
            1.0, -1.0, -1.0, //
            -1.0, 1.0, 1.0,
        ];
        let f1 = micro_f1(&ds, &[0, 1], &logits, 3);
        // tp=2 fp=1 fn=1 -> 2*2/(4+1+1) = 4/6
        assert!((f1 - 4.0 / 6.0).abs() < 1e-12, "f1={f1}");
    }

    #[test]
    fn perfect_predictions() {
        let ds = ds_multilabel();
        let logits = vec![
            1.0, 1.0, -1.0, //
            -1.0, -1.0, 1.0,
        ];
        assert_eq!(micro_f1(&ds, &[0, 1], &logits, 3), 1.0);
        assert_eq!(subset_accuracy(&ds, &[0, 1], &logits, 3), 1.0);
    }

    #[test]
    fn entropy_of_skewed_batch_is_lower() {
        let ds = ds_multiclass();
        let skewed = batch_label_entropy(&ds, &[0, 0, 0]);
        let uniform = batch_label_entropy(&ds, &[0, 1, 2]);
        assert!(skewed < uniform);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn argmax_skips_non_finite_entries() {
        assert_eq!(argmax_finite(&[f32::NAN, 1.0, 0.5]), Some(1));
        assert_eq!(argmax_finite(&[f32::NEG_INFINITY, -2.0]), Some(1));
        assert_eq!(argmax_finite(&[f32::NAN, f32::NAN]), None);
        assert_eq!(argmax_finite(&[f32::NEG_INFINITY; 3]), None);
        assert_eq!(argmax_finite(&[]), None);
    }

    /// Regression: an all-NaN logits row used to argmax to index 0 and
    /// silently score as "predicted class 0" — here node0's true label
    /// *is* 0, so the poisoned eval looked perfect.  It must score as
    /// wrong and tick the poisoned-row counter.
    #[test]
    fn multiclass_nan_row_scores_wrong() {
        let ds = ds_multiclass();
        let before = non_finite_rows();
        let logits = vec![
            f32::NAN, f32::NAN, f32::NAN, // node0 poisoned (label 0)
            0.0, 5.0, 0.0, //                node1 -> 1 (right)
            0.0, 0.0, 9.0, //                node2 -> 2 (right)
        ];
        let f1 = micro_f1(&ds, &[0, 1, 2], &logits, 3);
        assert!((f1 - 2.0 / 3.0).abs() < 1e-12, "f1={f1}");
        assert!(non_finite_rows() >= before + 1);
        // subset accuracy must not credit the poisoned row either
        let sa = subset_accuracy(&ds, &[0, 1, 2], &logits, 3);
        assert!((sa - 2.0 / 3.0).abs() < 1e-12, "sa={sa}");
    }

    /// Same class of bug with −inf saturation instead of NaN.
    #[test]
    fn multiclass_neg_inf_row_scores_wrong() {
        let ds = ds_multiclass();
        let before = non_finite_rows();
        let logits = vec![
            f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY, // node0
            0.0, 5.0, 0.0, //                                           node1
            0.0, 0.0, 9.0, //                                           node2
        ];
        let f1 = micro_f1(&ds, &[0, 1, 2], &logits, 3);
        assert!((f1 - 2.0 / 3.0).abs() < 1e-12, "f1={f1}");
        assert!(non_finite_rows() >= before + 1);
    }

    /// Multilabel: a poisoned row predicts every class negative, so its
    /// true labels count as false negatives — and the counter ticks.
    #[test]
    fn multilabel_nan_row_counts_labels_as_missed() {
        let ds = ds_multilabel();
        let before = non_finite_rows();
        let logits = vec![
            f32::NAN, f32::NAN, f32::NAN, // node0 poisoned (labels {0,1})
            -1.0, -1.0, 1.0, //              node1 exact ({2})
        ];
        let f1 = micro_f1(&ds, &[0, 1], &logits, 3);
        // tp=1 fp=0 fn=2 -> 2/(2+0+2) = 0.5
        assert!((f1 - 0.5).abs() < 1e-12, "f1={f1}");
        assert!(non_finite_rows() >= before + 1);
    }
}
