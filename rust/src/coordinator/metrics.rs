//! Evaluation metrics: micro-F1 (the paper's accuracy metric for both
//! the multi-class and multi-label tasks) and label entropy (Fig. 2).

use crate::graph::{Dataset, Labels, Task};

/// Micro-F1 over the given nodes from dense logits rows.
///
/// - multiclass: argmax prediction; micro-F1 == accuracy.
/// - multilabel: sigmoid(logit) > 0.5 ⇔ logit > 0 per class.
pub fn micro_f1(
    ds: &Dataset,
    nodes: &[u32],
    logits: &[f32],
    classes: usize,
) -> f64 {
    debug_assert_eq!(logits.len(), nodes.len() * classes);
    match ds.task {
        Task::Multiclass => {
            let mut correct = 0usize;
            for (i, &v) in nodes.iter().enumerate() {
                let row = &logits[i * classes..(i + 1) * classes];
                let pred = argmax(row);
                if ds.labels.has_label(v as usize, pred) {
                    correct += 1;
                }
            }
            if nodes.is_empty() {
                0.0
            } else {
                correct as f64 / nodes.len() as f64
            }
        }
        Task::Multilabel => {
            let (mut tp, mut fp, mut fnn) = (0u64, 0u64, 0u64);
            for (i, &v) in nodes.iter().enumerate() {
                let row = &logits[i * classes..(i + 1) * classes];
                for c in 0..classes {
                    let pred = row[c] > 0.0;
                    let truth = ds.labels.has_label(v as usize, c);
                    match (pred, truth) {
                        (true, true) => tp += 1,
                        (true, false) => fp += 1,
                        (false, true) => fnn += 1,
                        (false, false) => {}
                    }
                }
            }
            let denom = 2 * tp + fp + fnn;
            if denom == 0 {
                0.0
            } else {
                2.0 * tp as f64 / denom as f64
            }
        }
    }
}

pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Label-distribution entropy of a batch (Fig. 2); multiclass uses the
/// class histogram, multilabel the per-class positive counts.
pub fn batch_label_entropy(ds: &Dataset, nodes: &[u32]) -> f64 {
    let hist = ds.label_histogram(nodes);
    crate::util::entropy(&hist)
}

/// Fraction of exactly-matching label sets (subset accuracy; secondary
/// metric for multilabel sanity checks).
pub fn subset_accuracy(
    ds: &Dataset,
    nodes: &[u32],
    logits: &[f32],
    classes: usize,
) -> f64 {
    if nodes.is_empty() {
        return 0.0;
    }
    let mut exact = 0usize;
    for (i, &v) in nodes.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let ok = match &ds.labels {
            Labels::Multiclass(l) => argmax(row) == l[v as usize] as usize,
            Labels::Multilabel { .. } => (0..classes)
                .all(|c| (row[c] > 0.0) == ds.labels.has_label(v as usize, c)),
        };
        if ok {
            exact += 1;
        }
    }
    exact as f64 / nodes.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Csr, Split};

    fn ds_multiclass() -> Dataset {
        Dataset {
            name: "m".into(),
            task: Task::Multiclass,
            graph: Csr::from_edges(3, &[(0, 1)]),
            f_in: 1,
            num_classes: 3,
            features: vec![0.0; 3],
            labels: Labels::Multiclass(vec![0, 1, 2]),
            split: vec![Split::Train; 3],
        }
    }

    #[test]
    fn multiclass_f1_is_accuracy() {
        let ds = ds_multiclass();
        // predictions: node0 -> 0 (right), node1 -> 2 (wrong), node2 -> 2
        let logits = vec![
            5.0, 0.0, 0.0, //
            0.0, 1.0, 3.0, //
            0.0, 0.0, 9.0,
        ];
        let f1 = micro_f1(&ds, &[0, 1, 2], &logits, 3);
        assert!((f1 - 2.0 / 3.0).abs() < 1e-12);
    }

    fn ds_multilabel() -> Dataset {
        let mut labels = Labels::multilabel_new(2, 3);
        labels.set_label(0, 0);
        labels.set_label(0, 1);
        labels.set_label(1, 2);
        Dataset {
            name: "ml".into(),
            task: Task::Multilabel,
            graph: Csr::from_edges(2, &[(0, 1)]),
            f_in: 1,
            num_classes: 3,
            features: vec![0.0; 2],
            labels,
            split: vec![Split::Train; 2],
        }
    }

    #[test]
    fn multilabel_f1() {
        let ds = ds_multilabel();
        // node0 predicts {0} (1 tp, 1 fn); node1 predicts {1,2} (1 tp, 1 fp)
        let logits = vec![
            1.0, -1.0, -1.0, //
            -1.0, 1.0, 1.0,
        ];
        let f1 = micro_f1(&ds, &[0, 1], &logits, 3);
        // tp=2 fp=1 fn=1 -> 2*2/(4+1+1) = 4/6
        assert!((f1 - 4.0 / 6.0).abs() < 1e-12, "f1={f1}");
    }

    #[test]
    fn perfect_predictions() {
        let ds = ds_multilabel();
        let logits = vec![
            1.0, 1.0, -1.0, //
            -1.0, -1.0, 1.0,
        ];
        assert_eq!(micro_f1(&ds, &[0, 1], &logits, 3), 1.0);
        assert_eq!(subset_accuracy(&ds, &[0, 1], &logits, 3), 1.0);
    }

    #[test]
    fn entropy_of_skewed_batch_is_lower() {
        let ds = ds_multiclass();
        let skewed = batch_label_entropy(&ds, &[0, 0, 0]);
        let uniform = batch_label_entropy(&ds, &[0, 1, 2]);
        assert!(skewed < uniform);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
