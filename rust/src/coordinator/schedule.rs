//! Learning-rate schedules + early stopping for the training loop.
//!
//! The AOT `train_step` takes `lr` as a runtime scalar input, so
//! schedules are purely host-side policy — no artifact changes needed.

/// Per-epoch learning-rate policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    /// paper setting: constant (Adam, lr 0.01).
    Constant,
    /// multiply by `factor` every `every` epochs.
    StepDecay { every: usize, factor: f32 },
    /// linear decay from base to `end_frac * base` over the run.
    Linear { end_frac: f32 },
}

impl LrSchedule {
    pub fn lr_at(&self, base: f32, epoch: usize, total_epochs: usize) -> f32 {
        match *self {
            LrSchedule::Constant => base,
            LrSchedule::StepDecay { every, factor } => {
                let k = if every == 0 { 0 } else { (epoch - 1) / every };
                base * factor.powi(k as i32)
            }
            LrSchedule::Linear { end_frac } => {
                if total_epochs <= 1 {
                    return base;
                }
                let t = (epoch - 1) as f32 / (total_epochs - 1) as f32;
                base * (1.0 - t + t * end_frac)
            }
        }
    }
}

/// Early stopping on the eval metric (higher = better).
#[derive(Clone, Debug)]
pub struct EarlyStopper {
    /// stop after this many evals without improvement (0 = disabled).
    pub patience: usize,
    best: f64,
    since_best: usize,
}

impl EarlyStopper {
    pub fn new(patience: usize) -> EarlyStopper {
        EarlyStopper { patience, best: f64::NEG_INFINITY, since_best: 0 }
    }

    /// Record an eval; returns true when training should stop.
    pub fn update(&mut self, metric: f64) -> bool {
        if self.patience == 0 {
            return false;
        }
        if metric > self.best {
            self.best = metric;
            self.since_best = 0;
            false
        } else {
            self.since_best += 1;
            self.since_best >= self.patience
        }
    }

    pub fn best(&self) -> f64 {
        self.best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant() {
        assert_eq!(LrSchedule::Constant.lr_at(0.01, 5, 10), 0.01);
    }

    #[test]
    fn step_decay() {
        let s = LrSchedule::StepDecay { every: 10, factor: 0.5 };
        assert_eq!(s.lr_at(0.08, 1, 100), 0.08);
        assert_eq!(s.lr_at(0.08, 10, 100), 0.08);
        assert_eq!(s.lr_at(0.08, 11, 100), 0.04);
        assert_eq!(s.lr_at(0.08, 21, 100), 0.02);
    }

    #[test]
    fn linear() {
        let s = LrSchedule::Linear { end_frac: 0.1 };
        assert_eq!(s.lr_at(1.0, 1, 11), 1.0);
        assert!((s.lr_at(1.0, 11, 11) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(1.0, 6, 11) - 0.55).abs() < 1e-6);
    }

    #[test]
    fn early_stop_patience() {
        let mut e = EarlyStopper::new(2);
        assert!(!e.update(0.5));
        assert!(!e.update(0.6)); // improved
        assert!(!e.update(0.55)); // 1 since best
        assert!(e.update(0.58)); // 2 since best -> stop
        assert_eq!(e.best(), 0.6);
    }

    #[test]
    fn disabled_never_stops() {
        let mut e = EarlyStopper::new(0);
        for _ in 0..100 {
            assert!(!e.update(0.1));
        }
    }
}
