//! Out-of-core training + clustered evaluation over a [`GraphStorage`].
//!
//! The classic path ([`crate::session::Driver`] over a
//! [`super::source::ClusterSource`]) borrows a resident [`Dataset`];
//! at Amazon2M scale the adjacency + feature matrix never fit, so this
//! module provides the storage-generic twins:
//!
//! * [`StorageClusterSource`] — a [`BatchSource`] identical to
//!   `ClusterSource` in plan derivation (same epoch salt, same sampler
//!   stream) whose batches are assembled with lazy row reads
//!   ([`BatchAssembler::assemble_storage_into`]). On the `InRam` arm it
//!   produces bit-identical batches to `ClusterSource`; on the `OnDisk`
//!   arm, bit-identical batches to the `InRam` arm (pinned by the
//!   `store` test suite).
//! * [`train_storage`] — a closed epoch loop mirroring the driver's
//!   transitions (same lr schedule, loss accounting, eval cadence,
//!   early stopping, peak-memory accounting), minus the event plumbing
//!   the CLI paths don't need out-of-core.
//! * [`cluster_evaluate_storage`] — the paper-style clustered eval with
//!   *incremental* micro-F1 counting: per-batch forward passes fold
//!   integer counts instead of materializing the full `(n, classes)`
//!   logits matrix (800 MB at 2M nodes × 47 classes — defeating the
//!   point of out-of-core storage). Integer counts sum to exactly the
//!   gathered result, so this equals `batch_eval::cluster_evaluate`
//!   on a resident dataset with the same q=1 plan.
//!
//! Evaluation reuses the *training* clusters (re-batched one cluster at
//! a time), so no second partition of the full graph is ever computed
//! or held.

use anyhow::{anyhow, Result};

use crate::coordinator::batch::{Batch, BatchAssembler};
use crate::coordinator::sampler::ClusterSampler;
use crate::coordinator::schedule::EarlyStopper;
use crate::coordinator::source::{epoch_rng, BatchSource, SourceStats};
use crate::coordinator::trainer::{CurvePoint, TrainResult, TrainState};
use crate::graph::{GraphStorage, Split, Task};
use crate::norm::NormConfig;
use crate::runtime::{Backend, ModelSpec, Tensor};
use crate::session::TrainConfig;
use crate::util::{Rng, Timer};

/// Cluster-GCN's batch source over either storage arm; the storage twin
/// of [`super::source::ClusterSource`] (same epoch salt, same plan
/// stream, same accounting).
pub struct StorageClusterSource<'a> {
    store: &'a GraphStorage,
    sampler: ClusterSampler,
    assembler: BatchAssembler,
    seed: u64,
    plan: Vec<Vec<u32>>,
    nodes: Vec<u32>,
    within_edges: u64,
    batch_nodes: u64,
    max_batch_bytes: usize,
}

impl<'a> StorageClusterSource<'a> {
    /// Source over `store` with an owned sampler; errors when the
    /// largest possible batch cannot fit the model's padded batch size.
    pub fn new(
        store: &'a GraphStorage,
        sampler: ClusterSampler,
        spec: &ModelSpec,
        norm: NormConfig,
        seed: u64,
    ) -> Result<StorageClusterSource<'a>> {
        if sampler.max_batch_nodes() > spec.b_max {
            return Err(anyhow!(
                "sampler can produce {} nodes but the model has b_max={}",
                sampler.max_batch_nodes(),
                spec.b_max
            ));
        }
        Ok(StorageClusterSource {
            store,
            sampler,
            assembler: BatchAssembler::new(store.n(), spec.b_max, norm),
            seed,
            plan: Vec::new(),
            nodes: Vec::new(),
            within_edges: 0,
            batch_nodes: 0,
            max_batch_bytes: 0,
        })
    }
}

impl BatchSource for StorageClusterSource<'_> {
    fn shape(&self) -> (usize, usize, usize) {
        (self.assembler.b_max, self.store.f_in(), self.store.num_classes())
    }

    fn begin_epoch(&mut self, epoch: usize) -> usize {
        // same salt as ClusterSource: for a given (seed, epoch) both
        // sources draw the same plan over the same clusters
        let mut rng = epoch_rng(self.seed, 0x5A5A_0000_1111_2222, epoch);
        self.plan = self.sampler.epoch_plan(&mut rng);
        self.plan.len()
    }

    fn len(&self) -> usize {
        self.plan.len()
    }

    fn assemble(&mut self, i: usize, into: &mut Batch) {
        self.sampler.batch_nodes(&self.plan[i], &mut self.nodes);
        self.assembler.assemble_storage_into(self.store, &self.nodes, into);
        if into.n_train > 0 {
            self.within_edges += into.within_edges as u64;
            self.batch_nodes += into.n_real as u64;
            self.max_batch_bytes = self.max_batch_bytes.max(into.bytes());
        }
    }

    fn stats(&self) -> SourceStats {
        SourceStats {
            max_batch_bytes: self.max_batch_bytes,
            utilization: self.within_edges as f64 / self.batch_nodes.max(1) as f64,
        }
    }
}

/// Incremental micro-F1 accumulator: integer counts per batch, final
/// ratio once — exactly [`super::metrics::micro_f1`] restated as a
/// fold, so batching cannot change the result.
enum F1Counts {
    Multiclass { correct: u64, total: u64 },
    Multilabel { tp: u64, fp: u64, fnn: u64 },
}

impl F1Counts {
    fn new(task: Task) -> F1Counts {
        match task {
            Task::Multiclass => F1Counts::Multiclass { correct: 0, total: 0 },
            Task::Multilabel => F1Counts::Multilabel { tp: 0, fp: 0, fnn: 0 },
        }
    }

    fn add_node(&mut self, store: &GraphStorage, v: usize, row: &[f32]) {
        match self {
            F1Counts::Multiclass { correct, total } => {
                *total += 1;
                match crate::coordinator::metrics::argmax_finite(row) {
                    Some(p) if store.has_label(v, p) => *correct += 1,
                    Some(_) => {}
                    // poisoned row: wrong, and visible to the guard layer
                    None => crate::coordinator::metrics::note_non_finite_row(),
                }
            }
            F1Counts::Multilabel { tp, fp, fnn } => {
                for (c, &x) in row.iter().enumerate() {
                    match (x > 0.0, store.has_label(v, c)) {
                        (true, true) => *tp += 1,
                        (true, false) => *fp += 1,
                        (false, true) => *fnn += 1,
                        (false, false) => {}
                    }
                }
            }
        }
    }

    fn f1(&self) -> f64 {
        match *self {
            F1Counts::Multiclass { correct, total } => {
                if total == 0 {
                    0.0
                } else {
                    correct as f64 / total as f64
                }
            }
            F1Counts::Multilabel { tp, fp, fnn } => {
                let denom = 2 * tp + fp + fnn;
                if denom == 0 {
                    0.0
                } else {
                    2.0 * tp as f64 / denom as f64
                }
            }
        }
    }
}

/// Micro-F1 of `eval_split` via cluster-wise batched inference over the
/// training clusters (one cluster per batch), folding integer counts
/// per batch — never a full logits matrix. Storage-generic: identical
/// results on the `InRam` and `OnDisk` arms.
pub fn cluster_evaluate_storage(
    backend: &mut dyn Backend,
    store: &GraphStorage,
    sampler: &ClusterSampler,
    model: &str,
    weights: &[Tensor],
    norm: NormConfig,
    eval_split: Split,
    seed: u64,
) -> Result<f64> {
    let spec = backend.model_spec(model)?;
    backend.prepare(model)?;
    let classes = spec.classes;
    // q=1 over the training clusters: the plan covers every cluster
    // (chunks_exact(1) drops nothing), so each node is scored once
    let eval_sampler = ClusterSampler::new(sampler.clusters.clone(), 1);
    let mut assembler = BatchAssembler::new(store.n(), spec.b_max, norm);
    let mut batch = assembler.new_batch_storage(store);
    let mut rng = Rng::new(seed);
    let plan = eval_sampler.epoch_plan(&mut rng);
    let mut nodes = Vec::new();
    let mut counts = F1Counts::new(store.task());
    for ids in &plan {
        eval_sampler.batch_nodes(ids, &mut nodes);
        assembler.assemble_storage_into(store, &nodes, &mut batch);
        let rows = backend.forward(model, weights, &batch)?;
        for (i, &v) in nodes.iter().enumerate() {
            if store.split_of(v as usize) == eval_split {
                counts.add_node(
                    store,
                    v as usize,
                    &rows.data[i * classes..(i + 1) * classes],
                );
            }
        }
    }
    Ok(counts.f1())
}

/// Closed out-of-core training loop: the driver's epoch transitions
/// (lr schedule → epoch plan → `step_from` pulls → loss accounting →
/// clustered eval cadence → early stopping) over a [`GraphStorage`].
/// Identical losses/weights on both storage arms (pinned by tests).
pub fn train_storage(
    backend: &mut dyn Backend,
    store: &GraphStorage,
    sampler: &ClusterSampler,
    model: &str,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    let spec = backend.model_spec(model)?;
    backend.prepare(model)?;
    let mut state = TrainState::init(&spec, cfg.seed);
    let mut source =
        StorageClusterSource::new(store, sampler.clone(), &spec, cfg.norm, cfg.seed)?;
    let mut scratch = source.new_batch();
    let mut stopper = EarlyStopper::new(cfg.patience);
    let mut curve = Vec::new();
    let mut train_seconds = 0.0f64;
    let mut steps = 0u64;
    let mut stopped = false;

    for epoch in (cfg.start_epoch + 1)..=cfg.epochs {
        if stopped {
            break;
        }
        let lr = cfg.schedule.lr_at(cfg.lr, epoch, cfg.epochs);
        let t = Timer::start();
        backend.epoch_begin();
        let plan_len = source.begin_epoch(epoch);
        train_seconds += t.secs();

        let mut cursor = 0usize;
        let mut exec_steps = 0usize;
        let mut epoch_loss = 0.0f64;
        while cursor < plan_len {
            if cfg.max_steps_per_epoch > 0 && exec_steps >= cfg.max_steps_per_epoch {
                break;
            }
            let t = Timer::start();
            let outcome =
                backend.step_from(model, &mut state, lr, &mut source, cursor, &mut scratch)?;
            train_seconds += t.secs();
            cursor += outcome.consumed;
            if let Some(l) = outcome.loss {
                exec_steps += 1;
                steps += 1;
                epoch_loss += l as f64;
            }
        }
        let mean_loss = epoch_loss / exec_steps.max(1) as f64;

        let last = epoch == cfg.epochs;
        let due = cfg.eval_every > 0 && epoch % cfg.eval_every == 0;
        if due || last {
            let f1 = cluster_evaluate_storage(
                backend,
                store,
                sampler,
                model,
                &state.weights,
                cfg.norm,
                cfg.eval_split,
                cfg.seed,
            )?;
            curve.push(CurvePoint {
                epoch,
                train_seconds,
                train_loss: mean_loss,
                eval_f1: f1,
            });
            if stopper.update(f1) {
                stopped = true;
            }
        }
    }

    let stats = source.stats();
    let peak_bytes = stats.max_batch_bytes + state.param_bytes();
    Ok(TrainResult {
        state,
        curve,
        train_seconds,
        steps,
        peak_bytes,
        avg_within_edges_per_node: stats.utilization,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::source::ClusterSource;
    use crate::partition::{parts_to_clusters, Partitioner, RandomPartitioner};
    use crate::runtime::HostBackend;

    fn fixture() -> (crate::graph::Dataset, ClusterSampler, ModelSpec) {
        let ds = crate::datagen::build(crate::datagen::preset("cora_like").unwrap(), 5);
        let mut rng = Rng::new(3);
        let part = RandomPartitioner.partition(&ds.graph, 8, &mut rng);
        let sampler = ClusterSampler::new(parts_to_clusters(&part, 8), 2);
        let spec = ModelSpec::gcn(
            ds.task,
            2,
            ds.f_in,
            16,
            ds.num_classes,
            ds.n().next_multiple_of(8),
        );
        (ds, sampler, spec)
    }

    #[test]
    fn storage_source_matches_cluster_source_in_ram() {
        let (ds, sampler, spec) = fixture();
        let mut classic =
            ClusterSource::new(&ds, sampler.clone(), &spec, NormConfig::PAPER_DEFAULT, 7)
                .unwrap();
        let store = GraphStorage::InRam(ds.clone());
        let mut storage =
            StorageClusterSource::new(&store, sampler, &spec, NormConfig::PAPER_DEFAULT, 7)
                .unwrap();
        let na = classic.begin_epoch(2);
        let nb = storage.begin_epoch(2);
        assert_eq!(na, nb);
        assert!(na > 0);
        let mut ba = classic.new_batch();
        let mut bb = storage.new_batch();
        for i in 0..na {
            classic.assemble(i, &mut ba);
            storage.assemble(i, &mut bb);
            assert_eq!(ba.nodes, bb.nodes, "batch {i}");
            assert_eq!(ba.a.data, bb.a.data, "batch {i}");
            assert_eq!(ba.x.data, bb.x.data, "batch {i}");
            assert_eq!(ba.y.data, bb.y.data, "batch {i}");
        }
        assert_eq!(classic.stats().max_batch_bytes, storage.stats().max_batch_bytes);
    }

    #[test]
    fn train_storage_runs_and_records_curve() {
        let (ds, sampler, _) = fixture();
        let store = GraphStorage::InRam(ds);
        let mut backend = HostBackend::new();
        let cfg = TrainConfig {
            layers: 2,
            hidden: Some(16),
            epochs: 2,
            eval_every: 1,
            seed: 1,
            ..TrainConfig::default()
        };
        let spec = ModelSpec::gcn(
            store.task(),
            2,
            store.f_in(),
            16,
            store.num_classes(),
            store.n().next_multiple_of(8),
        );
        assert!(backend.register_model("m", spec));
        let out = train_storage(&mut backend, &store, &sampler, "m", &cfg).unwrap();
        assert_eq!(out.curve.len(), 2);
        assert!(out.steps > 0);
        assert!(out.peak_bytes > 0);
        for pt in &out.curve {
            assert!(pt.eval_f1.is_finite() && pt.train_loss.is_finite());
        }
    }
}
