//! Stochastic multiple-partition batch scheduler (§3.2, Fig. 3,
//! Algorithm 1 line 3): per epoch, shuffle the p clusters and emit
//! batches of q clusters *without replacement*; the batch assembler adds
//! back the between-cluster links of the union.

use crate::util::Rng;

#[derive(Clone)]
pub struct ClusterSampler {
    /// cluster node lists V_1..V_p (global node ids).
    pub clusters: Vec<Vec<u32>>,
    /// clusters per batch (q of §3.2).
    pub q: usize,
}

impl ClusterSampler {
    pub fn new(clusters: Vec<Vec<u32>>, q: usize) -> ClusterSampler {
        assert!(q >= 1 && q <= clusters.len());
        ClusterSampler { clusters, q }
    }

    /// Number of batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.clusters.len() / self.q
    }

    /// Largest possible batch (for b_max validation): sum of the q
    /// largest clusters.
    pub fn max_batch_nodes(&self) -> usize {
        let mut sizes: Vec<usize> = self.clusters.iter().map(|c| c.len()).collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        sizes.iter().take(self.q).sum()
    }

    /// One epoch's batch plan: a shuffled partition of cluster ids into
    /// groups of q (trailing remainder dropped, like the paper's
    /// without-replacement sampling).
    pub fn epoch_plan(&self, rng: &mut Rng) -> Vec<Vec<u32>> {
        let p = self.clusters.len();
        let mut ids: Vec<u32> = (0..p as u32).collect();
        rng.shuffle(&mut ids);
        ids.chunks_exact(self.q).map(|c| c.to_vec()).collect()
    }

    /// Materialize the node list of a batch (concatenated cluster
    /// members; order defines the local indexing).
    pub fn batch_nodes(&self, cluster_ids: &[u32], out: &mut Vec<u32>) {
        out.clear();
        for &c in cluster_ids {
            out.extend_from_slice(&self.clusters[c as usize]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sampler(p: usize, q: usize) -> ClusterSampler {
        let clusters: Vec<Vec<u32>> = (0..p)
            .map(|c| ((c * 10)..(c * 10 + 10)).map(|v| v as u32).collect())
            .collect();
        ClusterSampler::new(clusters, q)
    }

    #[test]
    fn plan_covers_all_clusters_once() {
        let s = sampler(10, 2);
        let mut rng = Rng::new(1);
        let plan = s.epoch_plan(&mut rng);
        assert_eq!(plan.len(), 5);
        let mut seen: Vec<u32> = plan.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn remainder_dropped() {
        let s = sampler(10, 3);
        let mut rng = Rng::new(2);
        let plan = s.epoch_plan(&mut rng);
        assert_eq!(plan.len(), 3);
        assert_eq!(s.batches_per_epoch(), 3);
    }

    #[test]
    fn plans_differ_across_epochs() {
        let s = sampler(12, 3);
        let mut rng = Rng::new(3);
        let p1 = s.epoch_plan(&mut rng);
        let p2 = s.epoch_plan(&mut rng);
        assert_ne!(p1, p2);
    }

    #[test]
    fn batch_nodes_concatenate() {
        let s = sampler(4, 2);
        let mut nodes = Vec::new();
        s.batch_nodes(&[2, 0], &mut nodes);
        assert_eq!(nodes.len(), 20);
        assert_eq!(nodes[0], 20);
        assert_eq!(nodes[10], 0);
    }

    #[test]
    fn max_batch_nodes() {
        let mut clusters = vec![vec![0; 5], vec![0; 9], vec![0; 7]];
        clusters[0] = (0..5).collect();
        clusters[1] = (5..14).collect();
        clusters[2] = (14..21).collect();
        let s = ClusterSampler::new(clusters, 2);
        assert_eq!(s.max_batch_nodes(), 16);
    }
}
