//! Cluster-wise inference through the PJRT `forward` artifacts: the
//! paper-style evaluation path where prediction, like training, runs on
//! block-diagonal cluster batches (between-batch links are dropped —
//! the Δ approximation of eq. (4) applied at eval time).
//!
//! `coordinator::inference` is the *exact* full-graph evaluator; this
//! module is the accelerated approximate one.  The integration suite
//! pins each batch against a host oracle, and `examples/perf_probe`
//! compares both paths' F1.

use anyhow::Result;

use crate::coordinator::batch::BatchAssembler;
use crate::coordinator::sampler::ClusterSampler;
use crate::graph::Dataset;
use crate::norm::NormConfig;
use crate::runtime::{Engine, Tensor};
use crate::util::Rng;

/// Run the forward artifact over every cluster batch; returns dense
/// (n, classes) logits assembled from the per-batch outputs.
pub fn cluster_forward(
    engine: &mut Engine,
    ds: &Dataset,
    sampler: &ClusterSampler,
    fwd_artifact: &str,
    weights: &[Tensor],
    norm: NormConfig,
    seed: u64,
) -> Result<Vec<f32>> {
    let meta = engine.meta(fwd_artifact)?;
    engine.ensure_compiled(fwd_artifact)?;
    let classes = meta.classes;
    let mut logits = vec![0f32; ds.n() * classes];
    let mut assembler = BatchAssembler::new(ds.n(), meta.b_max, norm);
    let mut batch = assembler.new_batch(ds);
    let mut rng = Rng::new(seed);
    let plan = sampler.epoch_plan(&mut rng);
    let mut nodes = Vec::new();
    for ids in &plan {
        sampler.batch_nodes(ids, &mut nodes);
        assembler.assemble_into(ds, &nodes, &mut batch);
        // weights + batch tensors go down by reference — no per-batch
        // clone of the parameter set or the assembled block
        let mut inputs: Vec<&Tensor> = weights.iter().collect();
        inputs.push(&batch.a);
        inputs.push(&batch.x);
        let out = engine.run_refs(fwd_artifact, &inputs)?;
        let rows = &out[0];
        for (i, &v) in nodes.iter().enumerate() {
            logits[v as usize * classes..(v as usize + 1) * classes]
                .copy_from_slice(&rows.data[i * classes..(i + 1) * classes]);
        }
    }
    Ok(logits)
}

/// Micro-F1 over `nodes` using cluster-wise PJRT inference.
pub fn cluster_evaluate(
    engine: &mut Engine,
    ds: &Dataset,
    sampler: &ClusterSampler,
    fwd_artifact: &str,
    weights: &[Tensor],
    norm: NormConfig,
    nodes: &[u32],
    seed: u64,
) -> Result<f64> {
    let logits = cluster_forward(engine, ds, sampler, fwd_artifact, weights, norm, seed)?;
    let rows = crate::coordinator::inference::gather_rows(&logits, ds.num_classes, nodes);
    Ok(crate::coordinator::metrics::micro_f1(
        ds,
        nodes,
        &rows,
        ds.num_classes,
    ))
}
