//! Cluster-wise inference through a backend's `forward` (the PJRT
//! `forward` artifacts or the host kernels): the paper-style evaluation
//! path where prediction, like training, runs on block-diagonal cluster
//! batches (between-batch links are dropped — the Δ approximation of
//! eq. (4) applied at eval time).
//!
//! `coordinator::inference` is the *exact* full-graph evaluator; this
//! module is the accelerated approximate one.  The integration suite
//! pins each batch against a host oracle, and `examples/perf_probe`
//! compares both paths' F1.

use anyhow::Result;

use crate::coordinator::batch::BatchAssembler;
use crate::coordinator::sampler::ClusterSampler;
use crate::graph::Dataset;
use crate::norm::NormConfig;
use crate::runtime::{Backend, Tensor};
use crate::util::Rng;

/// Run the forward model over every cluster batch; returns dense
/// (n, classes) logits assembled from the per-batch outputs.
pub fn cluster_forward(
    backend: &mut dyn Backend,
    ds: &Dataset,
    sampler: &ClusterSampler,
    fwd_model: &str,
    weights: &[Tensor],
    norm: NormConfig,
    seed: u64,
) -> Result<Vec<f32>> {
    let spec = backend.model_spec(fwd_model)?;
    backend.prepare(fwd_model)?;
    let classes = spec.classes;
    let mut logits = vec![0f32; ds.n() * classes];
    let mut assembler = BatchAssembler::new(ds.n(), spec.b_max, norm);
    let mut batch = assembler.new_batch(ds);
    let mut rng = Rng::new(seed);
    let plan = sampler.epoch_plan(&mut rng);
    let mut nodes = Vec::new();
    for ids in &plan {
        sampler.batch_nodes(ids, &mut nodes);
        assembler.assemble_into(ds, &nodes, &mut batch);
        let rows = backend.forward(fwd_model, weights, &batch)?;
        for (i, &v) in nodes.iter().enumerate() {
            logits[v as usize * classes..(v as usize + 1) * classes]
                .copy_from_slice(&rows.data[i * classes..(i + 1) * classes]);
        }
    }
    Ok(logits)
}

/// Micro-F1 over `nodes` using cluster-wise batched inference.
#[allow(clippy::too_many_arguments)]
pub fn cluster_evaluate(
    backend: &mut dyn Backend,
    ds: &Dataset,
    sampler: &ClusterSampler,
    fwd_model: &str,
    weights: &[Tensor],
    norm: NormConfig,
    nodes: &[u32],
    seed: u64,
) -> Result<f64> {
    let logits = cluster_forward(backend, ds, sampler, fwd_model, weights, norm, seed)?;
    let rows = crate::coordinator::inference::gather_rows(&logits, ds.num_classes, nodes);
    Ok(crate::coordinator::metrics::micro_f1(
        ds,
        nodes,
        &rows,
        ds.num_classes,
    ))
}
