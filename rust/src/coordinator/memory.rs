//! Memory accounting: the analytic models of Table 1 instantiated with
//! real dimensions, plus measured byte counts from the live runs.  Used
//! by the Table 5 / Table 8 benches.
//!
//! Following the paper (§1 footnote 1), the accounting covers the dense
//! embedding storage (the training bottleneck) + model/optimizer state;
//! the graph itself is excluded ("fixed and usually not the main
//! bottleneck").

/// Shared problem dimensions.
#[derive(Clone, Copy, Debug)]
pub struct Dims {
    pub n: usize,
    pub f_in: usize,
    pub f_hid: usize,
    pub classes: usize,
    pub layers: usize,
    /// batch size (real nodes for SGD methods).
    pub b: usize,
    /// neighbor samples per node (GraphSAGE r / VR-GCN r).
    pub r: usize,
    /// average degree (vanilla SGD expansion).
    pub d: f64,
}

const F32: usize = 4;

fn param_bytes(d: &Dims) -> usize {
    // W_0..W_{L-1} + Adam m, v
    let p = d.f_in * d.f_hid
        + d.f_hid * d.f_hid * d.layers.saturating_sub(2)
        + d.f_hid * d.classes;
    3 * p * F32
}

/// Cluster-GCN: O(b·L·F) embeddings (Table 1, last column).
pub fn cluster_gcn_bytes(d: &Dims) -> usize {
    let emb = d.b * d.f_in + d.b * d.f_hid * d.layers.saturating_sub(1)
        + d.b * d.classes;
    emb * F32 + param_bytes(d)
}

/// Full-batch GD / VR-GCN history: O(N·L·F) (Table 1).
pub fn full_embedding_bytes(d: &Dims) -> usize {
    let emb = d.n * d.f_in + d.n * d.f_hid * d.layers.saturating_sub(1);
    emb * F32 + param_bytes(d)
}

/// VR-GCN: history for every node & layer + the batch working set.
pub fn vrgcn_bytes(d: &Dims) -> usize {
    let history = d.n * d.f_hid * d.layers.saturating_sub(1);
    // batch receptive field with r samples: sum_{l<=L} b * (1+r)^l capped at n
    let field = receptive_field(d.b, 1.0 + d.r as f64, d.layers, d.n);
    let batch_emb: usize = field.iter().map(|&nodes| nodes * d.f_hid).sum();
    (history + batch_emb) * F32 + param_bytes(d)
}

/// GraphSAGE: O(b·r^L·F) working set (Table 1).
pub fn graphsage_bytes(d: &Dims) -> usize {
    let field = receptive_field(d.b, d.r as f64, d.layers, d.n);
    let emb: usize = field.iter().map(|&nodes| nodes * d.f_hid.max(d.f_in)).sum();
    emb * F32 + param_bytes(d)
}

/// Vanilla SGD: O(b·d^L·F) — full neighborhood expansion.
pub fn vanilla_sgd_bytes(d: &Dims) -> usize {
    let field = receptive_field(d.b, d.d, d.layers, d.n);
    let emb: usize = field.iter().map(|&nodes| nodes * d.f_hid.max(d.f_in)).sum();
    emb * F32 + param_bytes(d)
}

/// per-layer receptive-field sizes, geometric growth capped at n.
fn receptive_field(b: usize, factor: f64, layers: usize, n: usize) -> Vec<usize> {
    let mut sizes = Vec::with_capacity(layers + 1);
    let mut cur = b as f64;
    for _ in 0..=layers {
        sizes.push((cur as usize).min(n));
        cur *= factor.max(1.0);
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims {
            n: 100_000,
            f_in: 128,
            f_hid: 128,
            classes: 41,
            layers: 3,
            b: 1024,
            r: 2,
            d: 30.0,
        }
    }

    #[test]
    fn cluster_gcn_is_smallest() {
        let d = dims();
        let c = cluster_gcn_bytes(&d);
        assert!(c < vrgcn_bytes(&d), "cluster >= vrgcn");
        assert!(c < graphsage_bytes(&d), "cluster >= sage");
        assert!(c < vanilla_sgd_bytes(&d), "cluster >= vanilla");
        assert!(c < full_embedding_bytes(&d), "cluster >= full");
    }

    #[test]
    fn vrgcn_dominated_by_history() {
        let d = dims();
        // history alone: n * f_hid * (L-1) * 4
        let history = d.n * d.f_hid * 2 * 4;
        assert!(vrgcn_bytes(&d) > history);
    }

    #[test]
    fn cluster_memory_flat_in_layers() {
        // the paper's key memory claim: depth barely moves Cluster-GCN
        let mut d = dims();
        d.layers = 2;
        let m2 = cluster_gcn_bytes(&d);
        d.layers = 8;
        let m8 = cluster_gcn_bytes(&d);
        assert!(
            (m8 as f64) < (m2 as f64) * 5.0,
            "cluster-gcn memory blew up with depth"
        );
        // while vrgcn history scales with L
        d.layers = 2;
        let v2 = vrgcn_bytes(&d);
        d.layers = 8;
        let v8 = vrgcn_bytes(&d);
        assert!(v8 as f64 > v2 as f64 * 2.0);
    }

    #[test]
    fn receptive_field_caps_at_n() {
        let f = receptive_field(512, 30.0, 4, 10_000);
        assert_eq!(f.last().copied(), Some(10_000));
        assert_eq!(f[0], 512);
    }
}
