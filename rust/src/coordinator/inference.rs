//! Exact full-graph inference on the host (sparse Â, layered), used for
//! validation/test evaluation.
//!
//! The paper evaluates with the full normalized adjacency; a dense
//! (N, N) block is impossible beyond small N, so evaluation runs here as
//! CSR SpMM + dense GEMM over the *whole* graph with the weights trained
//! by the PJRT path.  Also doubles as an independent oracle for the
//! runtime parity tests (forward artifact vs host inference).

use crate::graph::{Csr, Dataset};
use crate::norm::{normalize_sparse, NormConfig};
use crate::runtime::Tensor;
use crate::util::pool::{default_threads, parallel_chunks};

/// y[n,g] = relu?(Â · x[n,f] · w[f,g]) for one layer, where Â is the
/// normalized sparse adjacency (vals aligned to g.cols + self loops).
pub fn spmm_layer(
    g: &Csr,
    vals: &[f32],
    self_loop: &[f32],
    x: &[f32],
    f: usize,
    w: &Tensor,
    relu: bool,
    threads: usize,
) -> Vec<f32> {
    let n = g.n();
    let (wf, wg) = (w.dims[0], w.dims[1]);
    assert_eq!(wf, f, "weight in-dim mismatch");
    debug_assert_eq!(x.len(), n * f);

    // P = Â X (row-parallel), then Z = P W fused per row block.
    let chunks = parallel_chunks(n, threads, |_, range| {
        let mut out = vec![0f32; range.len() * wg];
        let mut prop = vec![0f32; f];
        for (ri, v) in range.clone().enumerate() {
            // prop = sum_u Â[v,u] x[u] + self_loop[v] * x[v]
            prop.iter_mut().for_each(|p| *p = 0.0);
            let sl = self_loop[v];
            let xv = &x[v * f..(v + 1) * f];
            for j in 0..f {
                prop[j] = sl * xv[j];
            }
            for (idx, &u) in g.neighbors(v).iter().enumerate() {
                let a = vals[g.offsets[v] + idx];
                let xu = &x[u as usize * f..(u as usize + 1) * f];
                for j in 0..f {
                    prop[j] += a * xu[j];
                }
            }
            // z = prop @ W
            let row = &mut out[ri * wg..(ri + 1) * wg];
            for j in 0..f {
                let p = prop[j];
                if p == 0.0 {
                    continue;
                }
                let wrow = &w.data[j * wg..(j + 1) * wg];
                for k in 0..wg {
                    row[k] += p * wrow[k];
                }
            }
            if relu {
                row.iter_mut().for_each(|z| {
                    if *z < 0.0 {
                        *z = 0.0;
                    }
                });
            }
        }
        out
    });
    let mut out = Vec::with_capacity(n * wg);
    for c in chunks {
        out.extend_from_slice(&c);
    }
    out
}

/// Full L-layer forward over the entire graph; returns (n, classes)
/// logits.  `weights` in layer order.
pub fn full_forward(
    ds: &Dataset,
    weights: &[Tensor],
    norm: NormConfig,
    residual: bool,
) -> Vec<f32> {
    let threads = default_threads();
    let (vals, self_loop) = normalize_sparse(&ds.graph, norm);
    let mut h = ds.features.clone();
    let mut f = ds.f_in;
    let last = weights.len() - 1;
    for (l, w) in weights.iter().enumerate() {
        let z = spmm_layer(
            &ds.graph,
            &vals,
            &self_loop,
            &h,
            f,
            w,
            l != last,
            threads,
        );
        let g_dim = w.dims[1];
        h = if residual && l != last && g_dim == f {
            z.iter().zip(&h).map(|(a, b)| a + b).collect()
        } else {
            z
        };
        f = g_dim;
    }
    h
}

/// Gather logits rows for a node subset.
pub fn gather_rows(logits: &[f32], classes: usize, nodes: &[u32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(nodes.len() * classes);
    for &v in nodes {
        out.extend_from_slice(&logits[v as usize * classes..(v as usize + 1) * classes]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Labels, Split, Task};

    fn tiny_ds() -> Dataset {
        // path 0-1-2, f_in=2, 2 classes
        Dataset {
            name: "t".into(),
            task: Task::Multiclass,
            graph: Csr::from_edges(3, &[(0, 1), (1, 2)]),
            f_in: 2,
            num_classes: 2,
            features: vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
            labels: Labels::Multiclass(vec![0, 1, 0]),
            split: vec![Split::Train; 3],
        }
    }

    /// dense reference: logits = relu-chain over dense Â.
    fn dense_reference(ds: &Dataset, weights: &[Tensor], norm: NormConfig) -> Vec<f32> {
        let n = ds.n();
        let mut a = vec![0f32; n * n];
        let edges: Vec<(u32, u32)> = (0..n)
            .flat_map(|v| {
                ds.graph
                    .neighbors(v)
                    .iter()
                    .map(move |&u| (v as u32, u))
                    .collect::<Vec<_>>()
            })
            .collect();
        crate::norm::build_dense_block(n, &edges, n, norm, &mut a);
        let mut h = ds.features.clone();
        let mut f = ds.f_in;
        let last = weights.len() - 1;
        for (l, w) in weights.iter().enumerate() {
            let g_dim = w.dims[1];
            // p = a @ h
            let mut p = vec![0f32; n * f];
            for i in 0..n {
                for j in 0..n {
                    let av = a[i * n + j];
                    if av != 0.0 {
                        for t in 0..f {
                            p[i * f + t] += av * h[j * f + t];
                        }
                    }
                }
            }
            // z = p @ w
            let mut z = vec![0f32; n * g_dim];
            for i in 0..n {
                for t in 0..f {
                    let pv = p[i * f + t];
                    for k in 0..g_dim {
                        z[i * g_dim + k] += pv * w.data[t * g_dim + k];
                    }
                }
            }
            if l != last {
                z.iter_mut().for_each(|v| *v = v.max(0.0));
            }
            h = z;
            f = g_dim;
        }
        h
    }

    #[test]
    fn matches_dense_reference() {
        let ds = tiny_ds();
        let w0 = Tensor::new(vec![2, 4], (0..8).map(|i| 0.1 * i as f32 - 0.3).collect());
        let w1 = Tensor::new(vec![4, 2], (0..8).map(|i| 0.2 - 0.05 * i as f32).collect());
        let weights = vec![w0, w1];
        for norm in [NormConfig::PAPER_DEFAULT, NormConfig::ROW, NormConfig::ROW_LAMBDA1] {
            let fast = full_forward(&ds, &weights, norm, false);
            let slow = dense_reference(&ds, &weights, norm);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b} ({norm:?})");
            }
        }
    }

    #[test]
    fn residual_changes_result() {
        let ds = tiny_ds();
        // square hidden so residual applies: 2 -> 2 -> 2
        let w0 = Tensor::new(vec![2, 2], vec![0.5, -0.2, 0.3, 0.4]);
        let w1 = Tensor::new(vec![2, 2], vec![0.1, 0.2, -0.3, 0.4]);
        let plain = full_forward(&ds, &[w0.clone(), w1.clone()], NormConfig::ROW, false);
        let res = full_forward(&ds, &[w0, w1], NormConfig::ROW, true);
        assert!(plain.iter().zip(&res).any(|(a, b)| (a - b).abs() > 1e-7));
    }

    #[test]
    fn gather_rows_selects() {
        let logits = vec![1., 2., 3., 4., 5., 6.];
        assert_eq!(gather_rows(&logits, 2, &[2, 0]), vec![5., 6., 1., 2.]);
    }

    #[test]
    fn threads_equivalence() {
        let ds = tiny_ds();
        let (vals, sl) = normalize_sparse(&ds.graph, NormConfig::ROW);
        let w = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32 * 0.1).collect());
        let a = spmm_layer(&ds.graph, &vals, &sl, &ds.features, 2, &w, true, 1);
        let b = spmm_layer(&ds.graph, &vals, &sl, &ds.features, 2, &w, true, 4);
        assert_eq!(a, b);
    }
}
