//! Exact full-graph inference on the host (sparse Â, layered), used for
//! validation/test evaluation.
//!
//! The paper evaluates with the full normalized adjacency; a dense
//! (N, N) block is impossible beyond small N, so evaluation runs here as
//! CSR SpMM + dense GEMM over the *whole* graph with the weights trained
//! by the PJRT path.  Also doubles as an independent oracle for the
//! runtime parity tests (forward artifact vs host inference).
//!
//! ## Kernel architecture (see PERF.md)
//!
//! The production layer kernel [`spmm_layer_into`] is a cache-blocked
//! fusion of the two matmuls `Z = (Â·X)·W`:
//!
//! - rows are dispatched over the persistent [`crate::util::pool`] in
//!   contiguous chunks, each chunk writing its disjoint slice of the
//!   shared output buffer directly (no per-chunk `Vec` + concat copy);
//! - inside a chunk, rows are processed in blocks of [`ROW_BLOCK`]: the
//!   propagated rows `P = Â[rows]·X` land in a thread-local scratch,
//!   then the `P·W` GEMM runs tiled over ([`ROW_BLOCK`] × [`K_PANEL`] ×
//!   [`COL_TILE`]) so the active weight panel stays L1-resident while
//!   it is reused across all rows of the block.
//!
//! The k-accumulation order is ascending for every output element, so
//! the tiled kernel is bit-identical to the scalar oracle
//! [`spmm_layer_naive`] at every thread count — the parity property
//! tests rely on this.  Inner loops run through the `[f32; 8]`-chunked
//! `util::simd` helpers (element-independent, so still bit-identical).
//!
//! Every kernel also has a `*_raw_into` variant over raw
//! `offsets`/`cols` slices, so batch blocks
//! (`coordinator::batch::SparseBlock`) and full graphs ([`Csr`]) run
//! through the same code path — the host backend and the backward
//! engine (`runtime::backward`) build on these.

use std::cell::RefCell;

use crate::graph::{Csr, Dataset};
use crate::norm::{NormCache, NormConfig};
use crate::runtime::Tensor;
use crate::util::pool::{self, default_threads};
use crate::util::simd::{self, axpy};

/// Rows of Â propagated and multiplied per tile.
pub const ROW_BLOCK: usize = 64;
/// Columns of P (rows of W) per GEMM panel.
pub const K_PANEL: usize = 128;
/// Columns of W per GEMM tile (K_PANEL × COL_TILE × 4 B ≈ 32 KB ≈ L1).
pub const COL_TILE: usize = 64;

thread_local! {
    /// Per-worker propagation scratch (ROW_BLOCK × f), reused across
    /// layers and calls — the steady state allocates nothing.
    static PROP_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// y[n,g] = relu?(Â · x[n,f] · w[f,g]) for one layer, where Â is the
/// normalized sparse adjacency (vals aligned to g.cols + self loops).
/// Allocating wrapper over [`spmm_layer_into`].
#[allow(clippy::too_many_arguments)]
pub fn spmm_layer(
    g: &Csr,
    vals: &[f32],
    self_loop: &[f32],
    x: &[f32],
    f: usize,
    w: &Tensor,
    relu: bool,
    threads: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; g.n() * w.dims[1]];
    spmm_layer_into(g, vals, self_loop, x, f, w, relu, threads, &mut out);
    out
}

/// Fused tiled SpMM·GEMM layer writing into a caller-provided buffer
/// (`out.len() == n * w.dims[1]`; fully overwritten).  `threads` caps
/// the chunk count; the chunk layout (and therefore the result, bit for
/// bit) is independent of how many workers actually run.
#[allow(clippy::too_many_arguments)]
pub fn spmm_layer_into(
    g: &Csr,
    vals: &[f32],
    self_loop: &[f32],
    x: &[f32],
    f: usize,
    w: &Tensor,
    relu: bool,
    threads: usize,
    out: &mut [f32],
) {
    spmm_layer_raw_into(&g.offsets, &g.cols, vals, self_loop, x, f, w, relu, threads, out);
}

/// [`spmm_layer_into`] over a raw CSR view (`offsets`/`cols` slices
/// instead of a [`Csr`]) — the entry the host backend uses to run batch
/// blocks (`coordinator::batch::SparseBlock`) through the exact same
/// kernel as full-graph evaluation.
#[allow(clippy::too_many_arguments)]
pub fn spmm_layer_raw_into(
    offsets: &[usize],
    cols: &[u32],
    vals: &[f32],
    self_loop: &[f32],
    x: &[f32],
    f: usize,
    w: &Tensor,
    relu: bool,
    threads: usize,
    out: &mut [f32],
) {
    let n = offsets.len() - 1;
    let (wf, wg) = (w.dims[0], w.dims[1]);
    assert_eq!(wf, f, "weight in-dim mismatch");
    assert_eq!(out.len(), n * wg, "output buffer mismatch");
    debug_assert_eq!(self_loop.len(), n);
    debug_assert_eq!(x.len(), n * f);

    pool::global().run_rows_with(n, threads.max(1), wg, out, |_ci, rows, out_rows| {
        PROP_SCRATCH.with(|cell| {
            let mut prop = cell.borrow_mut();
            if prop.len() < ROW_BLOCK * f {
                prop.resize(ROW_BLOCK * f, 0.0);
            }
            spmm_block(
                offsets, cols, vals, self_loop, x, f, &w.data, wg, relu, rows, out_rows,
                &mut prop,
            );
        });
    });
}

/// [`spmm_layer_raw_into`] restricted to an explicit row subset: output
/// row `i` is the layer result for graph row `rows[i]`
/// (`out.len() == rows.len() * w.dims[1]`).  Every output row is a pure
/// per-row function of the inputs — the same propagate + ascending-k
/// tiled GEMM as the full kernel — so each row is bit-identical to the
/// corresponding row of [`spmm_layer_raw_into`] over the whole graph, at
/// any thread count and for any ordering of `rows`.  This is the
/// serving-cache entry point (`serve::cache` recomputes only the rows of
/// invalidated clusters).
#[allow(clippy::too_many_arguments)]
pub fn spmm_layer_rows_into(
    offsets: &[usize],
    cols: &[u32],
    vals: &[f32],
    self_loop: &[f32],
    x: &[f32],
    f: usize,
    w: &Tensor,
    relu: bool,
    rows: &[u32],
    threads: usize,
    out: &mut [f32],
) {
    let (wf, wg) = (w.dims[0], w.dims[1]);
    assert_eq!(wf, f, "weight in-dim mismatch");
    assert_eq!(out.len(), rows.len() * wg, "output buffer mismatch");
    debug_assert_eq!(self_loop.len(), offsets.len() - 1);

    pool::global().run_rows_with(rows.len(), threads.max(1), wg, out, |_ci, chunk, out_rows| {
        PROP_SCRATCH.with(|cell| {
            let mut prop = cell.borrow_mut();
            if prop.len() < ROW_BLOCK * f {
                prop.resize(ROW_BLOCK * f, 0.0);
            }
            spmm_block_gather(
                offsets,
                cols,
                vals,
                self_loop,
                x,
                f,
                &w.data,
                wg,
                relu,
                &rows[chunk],
                out_rows,
                &mut prop,
            );
        });
    });
}

/// One row-chunk of the fused kernel: propagate a ROW_BLOCK of rows,
/// then run the cache-tiled GEMM for that block, repeat.
#[allow(clippy::too_many_arguments)]
fn spmm_block(
    offsets: &[usize],
    cols: &[u32],
    vals: &[f32],
    self_loop: &[f32],
    x: &[f32],
    f: usize,
    w: &[f32],
    wg: usize,
    relu: bool,
    rows: std::ops::Range<usize>,
    out_rows: &mut [f32],
    prop: &mut [f32],
) {
    debug_assert_eq!(out_rows.len(), rows.len() * wg);
    let mut rb = rows.start;
    while rb < rows.end {
        let nb = ROW_BLOCK.min(rows.end - rb);

        // ---- P[nb, f] = Â[rb..rb+nb, :] · X -------------------------
        for ri in 0..nb {
            let v = rb + ri;
            let pr = &mut prop[ri * f..(ri + 1) * f];
            let sl = self_loop[v];
            let xv = &x[v * f..(v + 1) * f];
            for j in 0..f {
                pr[j] = sl * xv[j];
            }
            let off = offsets[v];
            for (idx, &u) in cols[off..offsets[v + 1]].iter().enumerate() {
                let a = vals[off + idx];
                let xu = &x[u as usize * f..(u as usize + 1) * f];
                axpy(pr, xu, a);
            }
        }

        // ---- Z[nb, wg] = P · W, tiled so the active W panel
        // (K_PANEL × COL_TILE) stays hot across all nb rows; each tile
        // runs on the dispatched register-blocked micro-kernel ---------
        let ob = (rb - rows.start) * wg;
        let out_block = &mut out_rows[ob..ob + nb * wg];
        out_block.fill(0.0);
        let mut kp = 0;
        while kp < f {
            let kn = K_PANEL.min(f - kp);
            let mut ct = 0;
            while ct < wg {
                let cn = COL_TILE.min(wg - ct);
                simd::gemm_tile(
                    &mut out_block[ct..],
                    wg,
                    &prop[kp..],
                    f,
                    1,
                    &w[kp * wg + ct..],
                    wg,
                    nb,
                    kn,
                    cn,
                );
                ct += cn;
            }
            kp += kn;
        }

        if relu {
            out_block.iter_mut().for_each(|z| {
                if *z < 0.0 {
                    *z = 0.0;
                }
            });
        }
        rb += nb;
    }
}

/// [`spmm_block`] with the row ids taken from an explicit list instead
/// of a contiguous range — same propagate, same tiled GEMM, same
/// ascending-k order, so each output row is bit-identical to the full
/// kernel's row for the same graph row.
#[allow(clippy::too_many_arguments)]
fn spmm_block_gather(
    offsets: &[usize],
    cols: &[u32],
    vals: &[f32],
    self_loop: &[f32],
    x: &[f32],
    f: usize,
    w: &[f32],
    wg: usize,
    relu: bool,
    rows: &[u32],
    out_rows: &mut [f32],
    prop: &mut [f32],
) {
    debug_assert_eq!(out_rows.len(), rows.len() * wg);
    let mut rb = 0;
    while rb < rows.len() {
        let nb = ROW_BLOCK.min(rows.len() - rb);

        // ---- P[nb, f] = Â[rows[rb..rb+nb], :] · X -------------------
        for ri in 0..nb {
            let v = rows[rb + ri] as usize;
            let pr = &mut prop[ri * f..(ri + 1) * f];
            let sl = self_loop[v];
            let xv = &x[v * f..(v + 1) * f];
            for j in 0..f {
                pr[j] = sl * xv[j];
            }
            let off = offsets[v];
            for (idx, &u) in cols[off..offsets[v + 1]].iter().enumerate() {
                let a = vals[off + idx];
                let xu = &x[u as usize * f..(u as usize + 1) * f];
                axpy(pr, xu, a);
            }
        }

        // ---- Z[nb, wg] = P · W, identical tiling to spmm_block ------
        let out_block = &mut out_rows[rb * wg..(rb + nb) * wg];
        out_block.fill(0.0);
        let mut kp = 0;
        while kp < f {
            let kn = K_PANEL.min(f - kp);
            let mut ct = 0;
            while ct < wg {
                let cn = COL_TILE.min(wg - ct);
                simd::gemm_tile(
                    &mut out_block[ct..],
                    wg,
                    &prop[kp..],
                    f,
                    1,
                    &w[kp * wg + ct..],
                    wg,
                    nb,
                    kn,
                    cn,
                );
                ct += cn;
            }
            kp += kn;
        }

        if relu {
            out_block.iter_mut().for_each(|z| {
                if *z < 0.0 {
                    *z = 0.0;
                }
            });
        }
        rb += nb;
    }
}

/// The original scalar single-thread layer — kept verbatim as the
/// parity oracle for the tiled kernel (property tests + table6 bench).
pub fn spmm_layer_naive(
    g: &Csr,
    vals: &[f32],
    self_loop: &[f32],
    x: &[f32],
    f: usize,
    w: &Tensor,
    relu: bool,
) -> Vec<f32> {
    let n = g.n();
    let (wf, wg) = (w.dims[0], w.dims[1]);
    assert_eq!(wf, f, "weight in-dim mismatch");
    debug_assert_eq!(x.len(), n * f);
    let mut out = vec![0f32; n * wg];
    let mut prop = vec![0f32; f];
    for v in 0..n {
        let sl = self_loop[v];
        let xv = &x[v * f..(v + 1) * f];
        for j in 0..f {
            prop[j] = sl * xv[j];
        }
        for (idx, &u) in g.neighbors(v).iter().enumerate() {
            let a = vals[g.offsets[v] + idx];
            let xu = &x[u as usize * f..(u as usize + 1) * f];
            for j in 0..f {
                prop[j] += a * xu[j];
            }
        }
        let row = &mut out[v * wg..(v + 1) * wg];
        for j in 0..f {
            let p = prop[j];
            if p == 0.0 {
                continue;
            }
            let wrow = &w.data[j * wg..(j + 1) * wg];
            for k in 0..wg {
                row[k] += p * wrow[k];
            }
        }
        if relu {
            row.iter_mut().for_each(|z| {
                if *z < 0.0 {
                    *z = 0.0;
                }
            });
        }
    }
    out
}

/// P = Â·X only (no weight GEMM), pooled.  Used by the perf probes to
/// attribute layer time between the SpMM and GEMM phases.
pub fn propagate_into(
    g: &Csr,
    vals: &[f32],
    self_loop: &[f32],
    x: &[f32],
    f: usize,
    threads: usize,
    out: &mut [f32],
) {
    propagate_raw_into(&g.offsets, &g.cols, vals, self_loop, x, f, threads, out);
}

/// [`propagate_into`] over a raw CSR view — shared with the host
/// backward engine, which stores the per-layer propagations `P_l`.
#[allow(clippy::too_many_arguments)]
pub fn propagate_raw_into(
    offsets: &[usize],
    cols: &[u32],
    vals: &[f32],
    self_loop: &[f32],
    x: &[f32],
    f: usize,
    threads: usize,
    out: &mut [f32],
) {
    let n = offsets.len() - 1;
    assert_eq!(out.len(), n * f, "propagate output mismatch");
    debug_assert_eq!(self_loop.len(), n);
    pool::global().run_rows_with(n, threads.max(1), f, out, |_ci, rows, out_rows| {
        for (ri, v) in rows.clone().enumerate() {
            let pr = &mut out_rows[ri * f..(ri + 1) * f];
            let sl = self_loop[v];
            let xv = &x[v * f..(v + 1) * f];
            for j in 0..f {
                pr[j] = sl * xv[j];
            }
            let off = offsets[v];
            for (idx, &u) in cols[off..offsets[v + 1]].iter().enumerate() {
                let a = vals[off + idx];
                let xu = &x[u as usize * f..(u as usize + 1) * f];
                axpy(pr, xu, a);
            }
        }
    });
}

/// Full L-layer forward over the entire graph; returns (n, classes)
/// logits.  `weights` in layer order.  Convenience wrapper that pays
/// one normalization; evaluation loops should hold a [`NormCache`] and
/// call [`full_forward_cached`].
pub fn full_forward(
    ds: &Dataset,
    weights: &[Tensor],
    norm: NormConfig,
    residual: bool,
) -> Vec<f32> {
    let mut cache = NormCache::new();
    full_forward_cached(ds, weights, norm, residual, &mut cache)
}

/// [`full_forward`] against a caller-owned normalization cache: the
/// O(nnz) `normalize_sparse` runs at most once per (dataset, config)
/// across all evaluations of a training run.  Layer activations
/// ping-pong between two max-width buffers — no per-layer allocation.
pub fn full_forward_cached(
    ds: &Dataset,
    weights: &[Tensor],
    norm: NormConfig,
    residual: bool,
    cache: &mut NormCache,
) -> Vec<f32> {
    let threads = default_threads();
    let adj = cache.get_or_compute(&ds.graph, norm);
    let n = ds.n();
    let max_w = weights
        .iter()
        .map(|w| w.dims[1])
        .chain([ds.f_in])
        .max()
        .expect("at least one layer");
    let mut cur = vec![0f32; n * max_w];
    cur[..n * ds.f_in].copy_from_slice(&ds.features);
    let mut nxt = vec![0f32; n * max_w];
    let mut f = ds.f_in;
    let last = weights.len() - 1;
    for (l, w) in weights.iter().enumerate() {
        let g_dim = w.dims[1];
        spmm_layer_into(
            &ds.graph,
            &adj.vals,
            &adj.self_loop,
            &cur[..n * f],
            f,
            w,
            l != last,
            threads,
            &mut nxt[..n * g_dim],
        );
        if residual && l != last && g_dim == f {
            for i in 0..n * f {
                nxt[i] += cur[i];
            }
        }
        std::mem::swap(&mut cur, &mut nxt);
        f = g_dim;
    }
    cur.truncate(n * f);
    cur
}

/// Gather logits rows for a node subset.
pub fn gather_rows(logits: &[f32], classes: usize, nodes: &[u32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(nodes.len() * classes);
    for &v in nodes {
        out.extend_from_slice(&logits[v as usize * classes..(v as usize + 1) * classes]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Labels, Split, Task};
    use crate::norm::normalize_sparse;

    fn tiny_ds() -> Dataset {
        // path 0-1-2, f_in=2, 2 classes
        Dataset {
            name: "t".into(),
            task: Task::Multiclass,
            graph: Csr::from_edges(3, &[(0, 1), (1, 2)]),
            f_in: 2,
            num_classes: 2,
            features: vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
            labels: Labels::Multiclass(vec![0, 1, 0]),
            split: vec![Split::Train; 3],
        }
    }

    /// dense reference: logits = relu-chain over dense Â.
    fn dense_reference(ds: &Dataset, weights: &[Tensor], norm: NormConfig) -> Vec<f32> {
        let n = ds.n();
        let mut a = vec![0f32; n * n];
        let edges: Vec<(u32, u32)> = (0..n)
            .flat_map(|v| {
                ds.graph
                    .neighbors(v)
                    .iter()
                    .map(move |&u| (v as u32, u))
                    .collect::<Vec<_>>()
            })
            .collect();
        crate::norm::build_dense_block(n, &edges, n, norm, &mut a);
        let mut h = ds.features.clone();
        let mut f = ds.f_in;
        let last = weights.len() - 1;
        for (l, w) in weights.iter().enumerate() {
            let g_dim = w.dims[1];
            // p = a @ h
            let mut p = vec![0f32; n * f];
            for i in 0..n {
                for j in 0..n {
                    let av = a[i * n + j];
                    if av != 0.0 {
                        for t in 0..f {
                            p[i * f + t] += av * h[j * f + t];
                        }
                    }
                }
            }
            // z = p @ w
            let mut z = vec![0f32; n * g_dim];
            for i in 0..n {
                for t in 0..f {
                    let pv = p[i * f + t];
                    for k in 0..g_dim {
                        z[i * g_dim + k] += pv * w.data[t * g_dim + k];
                    }
                }
            }
            if l != last {
                z.iter_mut().for_each(|v| *v = v.max(0.0));
            }
            h = z;
            f = g_dim;
        }
        h
    }

    #[test]
    fn matches_dense_reference() {
        let ds = tiny_ds();
        let w0 = Tensor::new(vec![2, 4], (0..8).map(|i| 0.1 * i as f32 - 0.3).collect());
        let w1 = Tensor::new(vec![4, 2], (0..8).map(|i| 0.2 - 0.05 * i as f32).collect());
        let weights = vec![w0, w1];
        for norm in [NormConfig::PAPER_DEFAULT, NormConfig::ROW, NormConfig::ROW_LAMBDA1] {
            let fast = full_forward(&ds, &weights, norm, false);
            let slow = dense_reference(&ds, &weights, norm);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b} ({norm:?})");
            }
        }
    }

    #[test]
    fn residual_changes_result() {
        let ds = tiny_ds();
        // square hidden so residual applies: 2 -> 2 -> 2
        let w0 = Tensor::new(vec![2, 2], vec![0.5, -0.2, 0.3, 0.4]);
        let w1 = Tensor::new(vec![2, 2], vec![0.1, 0.2, -0.3, 0.4]);
        let plain = full_forward(&ds, &[w0.clone(), w1.clone()], NormConfig::ROW, false);
        let res = full_forward(&ds, &[w0, w1], NormConfig::ROW, true);
        assert!(plain.iter().zip(&res).any(|(a, b)| (a - b).abs() > 1e-7));
    }

    #[test]
    fn gather_rows_selects() {
        let logits = vec![1., 2., 3., 4., 5., 6.];
        assert_eq!(gather_rows(&logits, 2, &[2, 0]), vec![5., 6., 1., 2.]);
    }

    #[test]
    fn threads_equivalence() {
        let ds = tiny_ds();
        let (vals, sl) = normalize_sparse(&ds.graph, NormConfig::ROW);
        let w = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32 * 0.1).collect());
        let a = spmm_layer(&ds.graph, &vals, &sl, &ds.features, 2, &w, true, 1);
        let b = spmm_layer(&ds.graph, &vals, &sl, &ds.features, 2, &w, true, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn tiled_matches_naive_bitwise() {
        // deterministic medium case crossing the tile boundaries
        let n = 150;
        let f = K_PANEL + 37; // force a partial second k-panel
        let wg = COL_TILE + 9; // force a partial second col tile
        let edges: Vec<(u32, u32)> =
            (0..n as u32 - 1).map(|v| (v, v + 1)).chain([(0, (n - 1) as u32)]).collect();
        let g = Csr::from_edges(n, &edges);
        let (vals, sl) = normalize_sparse(&g, NormConfig::PAPER_DEFAULT);
        let x: Vec<f32> = (0..n * f).map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.01).collect();
        let w = Tensor::new(
            vec![f, wg],
            (0..f * wg).map(|i| ((i * 13 % 97) as f32 - 48.0) * 0.02).collect(),
        );
        let oracle = spmm_layer_naive(&g, &vals, &sl, &x, f, &w, true);
        for threads in [1usize, 2, 5, 16] {
            let got = spmm_layer(&g, &vals, &sl, &x, f, &w, true, threads);
            assert_eq!(got, oracle, "threads={threads}");
        }
    }

    #[test]
    fn rows_kernel_matches_full_kernel_bitwise() {
        // same medium case as the tiled-vs-naive test, queried through
        // an unsorted, duplicated row subset at several thread counts
        let n = 150;
        let f = K_PANEL + 37;
        let wg = COL_TILE + 9;
        let edges: Vec<(u32, u32)> =
            (0..n as u32 - 1).map(|v| (v, v + 1)).chain([(0, (n - 1) as u32)]).collect();
        let g = Csr::from_edges(n, &edges);
        let (vals, sl) = normalize_sparse(&g, NormConfig::PAPER_DEFAULT);
        let x: Vec<f32> = (0..n * f).map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.01).collect();
        let w = Tensor::new(
            vec![f, wg],
            (0..f * wg).map(|i| ((i * 13 % 97) as f32 - 48.0) * 0.02).collect(),
        );
        let full = spmm_layer(&g, &vals, &sl, &x, f, &w, true, 4);
        let rows: Vec<u32> = vec![149, 0, 77, 3, 3, 148, 64, 65, 1];
        for threads in [1usize, 2, 8] {
            let mut got = vec![0f32; rows.len() * wg];
            spmm_layer_rows_into(
                &g.offsets, &g.cols, &vals, &sl, &x, f, &w, true, &rows, threads, &mut got,
            );
            assert_eq!(got, gather_rows(&full, wg, &rows), "threads={threads}");
        }
    }

    #[test]
    fn propagate_matches_layer_with_identity_weight() {
        let ds = tiny_ds();
        let (vals, sl) = normalize_sparse(&ds.graph, NormConfig::ROW);
        let eye = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let via_layer = spmm_layer(&ds.graph, &vals, &sl, &ds.features, 2, &eye, false, 2);
        let mut p = vec![0f32; ds.n() * 2];
        propagate_into(&ds.graph, &vals, &sl, &ds.features, 2, 2, &mut p);
        for (a, b) in p.iter().zip(&via_layer) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn cached_forward_matches_uncached() {
        let ds = tiny_ds();
        let w0 = Tensor::new(vec![2, 4], (0..8).map(|i| 0.1 * i as f32 - 0.3).collect());
        let w1 = Tensor::new(vec![4, 2], (0..8).map(|i| 0.2 - 0.05 * i as f32).collect());
        let weights = vec![w0, w1];
        let mut cache = NormCache::new();
        let a = full_forward_cached(&ds, &weights, NormConfig::ROW, false, &mut cache);
        let b = full_forward_cached(&ds, &weights, NormConfig::ROW, false, &mut cache);
        let c = full_forward(&ds, &weights, NormConfig::ROW, false);
        assert_eq!(a, b);
        assert_eq!(a, c);
        assert_eq!(cache.computes(), 1);
    }
}
