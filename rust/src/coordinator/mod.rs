//! The Cluster-GCN coordinator (the paper's system contribution at L3):
//! cluster-batch sampling, batch assembly + renormalization, the
//! [`BatchSource`] pull abstraction the training [`Driver`] consumes,
//! exact host evaluation, metrics, and memory accounting.  The
//! user-facing entry point is [`crate::session::Session`]; the driver
//! ([`crate::session::Driver`]) is the loop it hands you.
//!
//! [`Driver`]: crate::session::Driver

pub mod batch;
pub mod batch_eval;
pub mod checkpoint;
pub mod inference;
pub mod memory;
pub mod metrics;
pub mod sampler;
pub mod schedule;
pub mod source;
pub mod storage;
pub mod trainer;

pub use batch::{Batch, BatchAssembler, SparseBlock};
pub use sampler::ClusterSampler;
pub use schedule::{EarlyStopper, LrSchedule};
pub use source::{BatchSource, ClusterSource, SourceStats};
pub use storage::{cluster_evaluate_storage, train_storage, StorageClusterSource};
pub use trainer::{
    evaluate, evaluate_cached, train, train_observed, CurvePoint, TrainResult, TrainState,
};
