//! Deterministic load generation for the serving benchmarks: query
//! plans are pure functions of `(graph size, partition, LoadConfig)`
//! built on [`crate::util::Rng`] streams, so two runs with the same
//! seed replay the *same* byte-for-byte query sequence — the replay
//! determinism the serving tests and `BENCH_serve.json` digests pin.
//!
//! A plan models the knobs that move cache behavior: node popularity
//! (uniform vs power-law-ish hot set), batch size, and how often a
//! batch crosses cluster boundaries (cross-cluster queries fan the
//! cache's need-sets out through partition dependencies).

use std::time::Instant;

use anyhow::Result;

use crate::util::Rng;

use super::error::ServeError;
use super::server::Server;

/// Node-popularity model for generated queries.
#[derive(Clone, Copy, Debug)]
pub enum Mix {
    /// Every node equally likely.
    Uniform,
    /// A fixed random hot set absorbs most of the traffic — the
    /// skewed-popularity regime where an activation cache shines.
    Hotset {
        /// Fraction of nodes in the hot set (clamped to at least one
        /// node).
        hot_frac: f64,
        /// Probability a query's anchor node is drawn from the hot set.
        hot_weight: f64,
    },
}

/// Query-plan shape.
#[derive(Clone, Copy, Debug)]
pub struct LoadConfig {
    /// Popularity model.
    pub mix: Mix,
    /// Number of queries in the plan.
    pub queries: usize,
    /// Nodes per query (1 = single-node point lookups).
    pub batch: usize,
    /// Probability each non-anchor batch member is drawn globally
    /// instead of from the anchor's own cluster.
    pub cross_frac: f64,
    /// Stream seed; same seed ⇒ same plan.
    pub seed: u64,
}

impl Default for LoadConfig {
    fn default() -> LoadConfig {
        LoadConfig {
            mix: Mix::Uniform,
            queries: 1000,
            batch: 1,
            cross_frac: 0.1,
            seed: 42,
        }
    }
}

/// Latency/throughput report from [`run_load`]; times in microseconds.
#[derive(Clone, Copy, Debug)]
pub struct LoadReport {
    /// Wall-clock of the whole run (seconds).
    pub wall_secs: f64,
    /// Queries per second over the whole run.
    pub qps: f64,
    /// Mean per-query latency.
    pub mean_us: f64,
    /// Median per-query latency (nearest-rank).
    pub p50_us: f64,
    /// 99th-percentile per-query latency (nearest-rank, so always
    /// ≥ `p50_us`).
    pub p99_us: f64,
    /// Order-independent digest over every *successful* response's
    /// bits — equal digests across runs/client-counts pin
    /// byte-identical serving (only meaningful when `shed`, `timeouts`
    /// and `errors` are all zero, since a rejected query contributes
    /// nothing).
    pub digest: u64,
    /// queries answered successfully (latency stats cover only these).
    pub ok: u64,
    /// queries shed by admission control
    /// ([`ServeError::Overloaded`]).
    pub shed: u64,
    /// queries whose deadline expired
    /// ([`ServeError::DeadlineExceeded`]).
    pub timeouts: u64,
    /// queries failing with any other typed [`ServeError`] (panicked
    /// flushes, injected faults) — never a client panic.
    pub errors: u64,
}

/// Build a deterministic query plan over a graph of `n` nodes
/// partitioned into `clusters` (with `owner[v]` the owning cluster).
pub fn generate(
    n: usize,
    owner: &[u32],
    clusters: &[Vec<u32>],
    cfg: &LoadConfig,
) -> Vec<Vec<u32>> {
    assert!(n > 0, "empty graph");
    assert_eq!(owner.len(), n, "owner table must cover the graph");
    let mut rng = Rng::new(cfg.seed ^ 0x5EAF_00D5);
    let hot: Vec<u32> = match cfg.mix {
        Mix::Uniform => Vec::new(),
        Mix::Hotset { hot_frac, .. } => {
            let mut perm: Vec<u32> = (0..n as u32).collect();
            let mut r = rng.split(1);
            r.shuffle(&mut perm);
            let k = ((n as f64 * hot_frac).ceil() as usize).clamp(1, n);
            perm.truncate(k);
            perm
        }
    };
    let batch = cfg.batch.max(1);
    let mut plan = Vec::with_capacity(cfg.queries);
    for _ in 0..cfg.queries {
        let anchor = match cfg.mix {
            Mix::Uniform => rng.usize_below(n) as u32,
            Mix::Hotset { hot_weight, .. } => {
                if rng.bool_with(hot_weight) {
                    hot[rng.usize_below(hot.len())]
                } else {
                    rng.usize_below(n) as u32
                }
            }
        };
        let mut q = Vec::with_capacity(batch);
        q.push(anchor);
        let home = &clusters[owner[anchor as usize] as usize];
        for _ in 1..batch {
            let v = if !home.is_empty() && !rng.bool_with(cfg.cross_frac) {
                home[rng.usize_below(home.len())]
            } else {
                rng.usize_below(n) as u32
            };
            q.push(v);
        }
        plan.push(q);
    }
    plan
}

/// One client's tally: latencies of successful queries plus the typed
/// outcome counters.
#[derive(Default)]
struct ClientShard {
    lats: Vec<f64>,
    digest: u64,
    ok: u64,
    shed: u64,
    timeouts: u64,
    errors: u64,
}

/// Replay a query plan against a server from `clients` concurrent
/// threads (client `k` takes queries `k, k+clients, …`), timing each
/// successful query and folding its response into an order-independent
/// digest.  Typed failures — [`ServeError::Overloaded`] sheds,
/// [`ServeError::DeadlineExceeded`] expiries, anything else — are
/// *counted*, never panicked on and never aborting the run: an
/// overloaded server produces a report with nonzero `shed`, not a dead
/// load generator.
pub fn run_load(server: &Server<'_>, queries: &[Vec<u32>], clients: usize) -> Result<LoadReport> {
    let clients = clients.clamp(1, queries.len().max(1));
    let start = Instant::now();
    let mut shards: Vec<ClientShard> = Vec::with_capacity(clients);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(clients);
        for k in 0..clients {
            handles.push(s.spawn(move || -> ClientShard {
                let mut shard = ClientShard::default();
                for (qi, q) in queries.iter().enumerate().skip(k).step_by(clients) {
                    let t = Instant::now();
                    match server.query(q) {
                        Ok(resp) => {
                            shard.ok += 1;
                            // floor keeps p50 strictly positive even
                            // when a warm single-row hit is faster than
                            // the clock tick
                            shard.lats.push((t.elapsed().as_secs_f64() * 1e6).max(1e-3));
                            shard.digest = shard
                                .digest
                                .wrapping_add(response_digest(qi as u64, &resp));
                        }
                        Err(ServeError::Overloaded { .. }) => shard.shed += 1,
                        Err(ServeError::DeadlineExceeded { .. }) => shard.timeouts += 1,
                        Err(_) => shard.errors += 1,
                    }
                }
                shard
            }));
        }
        for h in handles {
            shards.push(h.join().expect("load client panicked"));
        }
    });
    let wall = start.elapsed().as_secs_f64();
    let mut lats: Vec<f64> = Vec::new();
    let (mut digest, mut ok, mut shed, mut timeouts, mut errors) = (0u64, 0u64, 0u64, 0u64, 0u64);
    for sh in shards {
        lats.extend_from_slice(&sh.lats);
        digest = digest.wrapping_add(sh.digest);
        ok += sh.ok;
        shed += sh.shed;
        timeouts += sh.timeouts;
        errors += sh.errors;
    }
    lats.sort_unstable_by(|a, b| a.partial_cmp(b).expect("latency is never NaN"));
    let mean = if lats.is_empty() {
        0.0
    } else {
        lats.iter().sum::<f64>() / lats.len() as f64
    };
    Ok(LoadReport {
        wall_secs: wall,
        qps: lats.len() as f64 / wall.max(1e-9),
        mean_us: mean,
        p50_us: pct(&lats, 0.50),
        p99_us: pct(&lats, 0.99),
        digest,
        ok,
        shed,
        timeouts,
        errors,
    })
}

/// Nearest-rank percentile over a sorted slice (monotone in `q`, so
/// p99 ≥ p50 by construction).
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Per-query digest: a salted FNV-style fold over the response bits.
/// Queries fold in their plan index, so the whole-run digest (a
/// wrapping sum) is independent of client count and completion order.
fn response_digest(salt: u64, resp: &[f32]) -> u64 {
    let mut h = salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for &x in resp {
        h = h.wrapping_mul(0x100_0000_01B3).wrapping_add(x.to_bits() as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_and_respect_shape() {
        let clusters = vec![vec![0u32, 1, 2], vec![3, 4], vec![5, 6, 7]];
        let owner = vec![0u32, 0, 0, 1, 1, 2, 2, 2];
        let cfg = LoadConfig {
            mix: Mix::Hotset { hot_frac: 0.25, hot_weight: 0.9 },
            queries: 64,
            batch: 3,
            cross_frac: 0.2,
            seed: 7,
        };
        let a = generate(8, &owner, &clusters, &cfg);
        let b = generate(8, &owner, &clusters, &cfg);
        assert_eq!(a, b, "same seed must replay the same plan");
        assert_eq!(a.len(), 64);
        assert!(a.iter().all(|q| q.len() == 3 && q.iter().all(|&v| v < 8)));
        let c = generate(8, &owner, &clusters, &LoadConfig { seed: 8, ..cfg });
        assert_ne!(a, c, "different seeds should diverge");
    }

    #[test]
    fn percentiles_are_nearest_rank_and_monotone() {
        let lats: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(pct(&lats, 0.50), 50.0);
        assert_eq!(pct(&lats, 0.99), 99.0);
        assert_eq!(pct(&lats, 1.0), 100.0);
        assert!(pct(&lats, 0.99) >= pct(&lats, 0.50));
        assert_eq!(pct(&[], 0.5), 0.0);
    }
}
