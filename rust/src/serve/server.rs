//! The synchronous in-process serving front: glues the
//! [`Coalescer`] to one of two execution engines behind a weight-install
//! hook.
//!
//! - [`ServeMode::ExactCached`] (default): the partition-keyed
//!   [`ActivationCache`] over the full-graph normalized adjacency —
//!   responses are bit-identical to rows of the offline
//!   [`crate::coordinator::inference::full_forward_cached`] forward,
//!   in every cache state.
//! - [`ServeMode::Clustered`]: the Cluster-GCN **training**
//!   approximation served online — each flush groups queries by owning
//!   partition, assembles one (clusters ∪ halo) subgraph per group
//!   through the zero-alloc [`BatchAssembler`] (block-renormalized
//!   adjacency, so responses are Δ-approximate, not bit-identical —
//!   except with a single partition, where the block *is* the full
//!   graph and parity holds bitwise), and double-buffers assembly
//!   against execution via [`pool::pipeline`] so flush-group `i+1`
//!   assembles while `i` runs the kernels.
//!
//! ## Degradation ladder (PR 8)
//!
//! With [`ServeConfig::degrade_after`] > 0, an exact-mode server
//! watches flush sizes: a flush that drained a full queue
//! (`≥ queue_capacity` merged requests) is *pressured*.  After
//! `degrade_after` consecutive pressured flushes the server steps down
//! to a pre-built **halo-free** [`ServeMode::Clustered`] engine (each
//! cluster forwarded without its neighbor ring — a halo budget of
//! zero, the cheapest per-flush approximation) and steps back up the
//! moment a flush is not pressured.  Degraded responses are
//! approximate by design; shed/timeout/degraded counters surface in
//! [`ServerStats`] and `BENCH_serve.json`.  Every failure is a typed
//! [`ServeError`] — a panicked flush poisons no request but its own
//! riders (the engine lock recovers, the exact cache version is bumped
//! so no partially-written activation is ever served).
//!
//! A socket transport is deliberately out of scope here (ROADMAP item
//! 4); callers are in-process threads sharing `&Server`.

use std::sync::{Mutex, MutexGuard};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::checkpoint;
use crate::coordinator::inference::spmm_layer_raw_into;
use crate::coordinator::{Batch, BatchAssembler};
use crate::graph::Dataset;
use crate::norm::NormConfig;
use crate::runtime::Tensor;
use crate::util::{failpoint, pool};

use super::cache::ActivationCache;
use super::coalesce::Coalescer;
use super::error::ServeError;

/// Which execution engine answers flushes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeMode {
    /// Partition-keyed activation cache over the full-graph
    /// normalization; bit-identical to the offline forward.
    ExactCached,
    /// Per-flush (clusters ∪ halo) subgraph forward with block
    /// renormalization — the training-time approximation served online.
    Clustered,
}

/// Server construction knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Execution engine (see [`ServeMode`]).
    pub mode: ServeMode,
    /// Bounded coalescer queue depth (≥ 1); submitters beyond it block
    /// (or shed, see `shed_when_full`) until the active flush drains.
    pub queue_capacity: usize,
    /// Kernel thread cap for the engine.
    pub threads: usize,
    /// Shed at-capacity submissions with [`ServeError::Overloaded`]
    /// instead of blocking (admission control; default off).
    pub shed_when_full: bool,
    /// Per-request deadline in milliseconds (0 = none): bounds queue
    /// wait + response wait with [`ServeError::DeadlineExceeded`].
    pub deadline_ms: u64,
    /// Degrade to the halo-free clustered engine after this many
    /// consecutive full-queue flushes (0 = never degrade; exact mode
    /// only — a clustered server is already the cheap engine).
    pub degrade_after: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            mode: ServeMode::ExactCached,
            queue_capacity: 64,
            threads: pool::default_threads(),
            shed_when_full: false,
            deadline_ms: 0,
            degrade_after: 0,
        }
    }
}

/// Combined serving counters: coalescer + (exact-mode) cache +
/// degradation ladder.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    /// queries answered.
    pub queries: u64,
    /// engine flushes executed (< queries means coalescing merged
    /// concurrent requests).
    pub flushes: u64,
    /// largest number of requests merged into one flush.
    pub max_flush: usize,
    /// cache entry hits (exact mode; 0 in clustered mode).
    pub hits: u64,
    /// cache entries computed (exact mode; 0 in clustered mode).
    pub misses: u64,
    /// stale cache entries overwritten after invalidation (exact mode).
    pub evictions: u64,
    /// requests shed at admission ([`ServeError::Overloaded`]).
    pub shed: u64,
    /// requests whose deadline expired.
    pub timeouts: u64,
    /// flushes whose executor panicked (riders got
    /// [`ServeError::EnginePanicked`]; the server recovered).
    pub flush_panics: u64,
    /// flushes answered by the degraded halo-free clustered engine.
    pub degraded_flushes: u64,
}

/// Exact-mode or clustered-mode state, plus the served weights, all
/// behind one lock so a flush always sees a consistent
/// (weights, cache-version) pair.
struct Engine {
    weights: Vec<Tensor>,
    exact: Option<ActivationCache>,
    clustered: Option<Clustered>,
    /// halo-free clustered engine the degradation ladder steps down to
    /// (built upfront when `degrade_after` > 0 on an exact server).
    degraded: Option<Clustered>,
    /// consecutive pressured (full-queue) flushes.
    pressure_streak: usize,
    /// flushes served degraded.
    degraded_flushes: u64,
}

/// The in-process serving front.  Share `&Server` across caller
/// threads; every public method takes `&self`.
pub struct Server<'a> {
    ds: &'a Dataset,
    mode: ServeMode,
    classes: usize,
    clusters: Vec<Vec<u32>>,
    owner: Vec<u32>,
    engine: Mutex<Engine>,
    coalescer: Coalescer,
    queue_capacity: usize,
    degrade_after: usize,
}

impl<'a> Server<'a> {
    /// Build a server over `ds` partitioned into `clusters` (every node
    /// in exactly one cluster), serving `weights` trained under
    /// `(norm, residual)`.
    pub fn new(
        ds: &'a Dataset,
        clusters: Vec<Vec<u32>>,
        weights: Vec<Tensor>,
        norm: NormConfig,
        residual: bool,
        cfg: ServeConfig,
    ) -> Result<Server<'a>> {
        if weights.is_empty() {
            bail!("serving needs at least one layer of weights");
        }
        if weights[0].dims[0] != ds.f_in {
            bail!(
                "layer 0 expects {} input features, dataset has {}",
                weights[0].dims[0],
                ds.f_in
            );
        }
        for l in 1..weights.len() {
            if weights[l].dims[0] != weights[l - 1].dims[1] {
                bail!(
                    "layer {l} in-dim {} != layer {} out-dim {}",
                    weights[l].dims[0],
                    l - 1,
                    weights[l - 1].dims[1]
                );
            }
        }
        let covered: usize = clusters.iter().map(|c| c.len()).sum();
        if covered != ds.n() {
            bail!("clusters cover {covered} nodes, graph has {}", ds.n());
        }
        let mut owner = vec![0u32; ds.n()];
        for (c, nodes) in clusters.iter().enumerate() {
            for &v in nodes {
                owner[v as usize] = c as u32;
            }
        }
        let classes = weights.last().unwrap().dims[1];
        let threads = cfg.threads.max(1);
        let degrade_after = match cfg.mode {
            ServeMode::ExactCached => cfg.degrade_after,
            // a clustered server is already the cheap engine
            ServeMode::Clustered => 0,
        };
        let degraded = if degrade_after > 0 {
            Some(Clustered::new(ds, &clusters, norm, residual, threads, false))
        } else {
            None
        };
        let engine = match cfg.mode {
            ServeMode::ExactCached => Engine {
                weights,
                exact: Some(ActivationCache::new(
                    ds,
                    clusters.clone(),
                    norm,
                    residual,
                    threads,
                )),
                clustered: None,
                degraded,
                pressure_streak: 0,
                degraded_flushes: 0,
            },
            ServeMode::Clustered => Engine {
                weights,
                exact: None,
                clustered: Some(Clustered::new(ds, &clusters, norm, residual, threads, true)),
                degraded,
                pressure_streak: 0,
                degraded_flushes: 0,
            },
        };
        let queue_capacity = cfg.queue_capacity.max(1);
        Ok(Server {
            ds,
            mode: cfg.mode,
            classes,
            clusters,
            owner,
            engine: Mutex::new(engine),
            coalescer: Coalescer::with_policy(
                queue_capacity,
                cfg.shed_when_full,
                cfg.deadline_ms,
            ),
            queue_capacity,
            degrade_after,
        })
    }

    /// Lock the engine, recovering from poison: a flush that panicked
    /// while holding the lock may have left a partially-written cache
    /// entry, so recovery bumps the exact cache's version — every entry
    /// written under the poisoned generation recomputes before it is
    /// ever served.  (The clustered engines keep no cross-flush state,
    /// so they need no recovery.)
    fn lock_engine(&self) -> MutexGuard<'_, Engine> {
        match self.engine.lock() {
            Ok(g) => g,
            Err(p) => {
                let mut g = p.into_inner();
                if let Some(cache) = g.exact.as_mut() {
                    cache.bump_version();
                }
                g
            }
        }
    }

    /// Final-layer rows for `nodes`, row-major `nodes.len() × classes`
    /// (duplicates allowed, any order).  Blocks until the flush carrying
    /// this request executes; concurrent callers are coalesced.  Every
    /// failure is a typed [`ServeError`] — overload shedding, deadline
    /// expiry, a panicked flush — never a panic or a hang.
    pub fn query(&self, nodes: &[u32]) -> std::result::Result<Vec<f32>, ServeError> {
        let n = self.ds.n();
        for &v in nodes {
            if v as usize >= n {
                return Err(ServeError::NodeOutOfRange { node: v, n });
            }
        }
        self.coalescer.run(nodes.to_vec(), |lists| self.execute(lists))
    }

    /// Single-node convenience wrapper over [`Server::query`].
    pub fn query_one(&self, v: u32) -> std::result::Result<Vec<f32>, ServeError> {
        self.query(&[v])
    }

    /// Install new weights (the `apply_grads` / checkpoint-load
    /// integration point).  Shapes must match the served model exactly;
    /// in exact mode this bumps the cache version so no stale activation
    /// is ever served.
    pub fn install_weights(&self, weights: Vec<Tensor>) -> Result<()> {
        let mut eng = self.lock_engine();
        if weights.len() != eng.weights.len() {
            bail!(
                "weight install has {} layers, model has {}",
                weights.len(),
                eng.weights.len()
            );
        }
        for (l, (nw, ow)) in weights.iter().zip(&eng.weights).enumerate() {
            if nw.dims != ow.dims {
                bail!(
                    "layer {l} shape {:?} != served shape {:?}",
                    nw.dims,
                    ow.dims
                );
            }
        }
        eng.weights = weights;
        if let Some(cache) = eng.exact.as_mut() {
            cache.bump_version();
        }
        Ok(())
    }

    /// Load a versioned checkpoint (any `CGCNCKP*` version; v3 files
    /// are CRC-verified) and install its weights; returns the
    /// checkpoint's epoch.
    pub fn load_checkpoint(&self, path: &std::path::Path) -> Result<usize> {
        let ck = checkpoint::load_full(path)?;
        self.install_weights(ck.state.weights)
            .map_err(|e| anyhow!("checkpoint {}: {e}", path.display()))?;
        Ok(ck.epoch)
    }

    /// Precompute every cache entry at the current weights (exact mode;
    /// a no-op in clustered mode, which keeps no cross-flush state).
    pub fn warm(&self) {
        let mut guard = self.lock_engine();
        let eng = &mut *guard;
        if let Some(cache) = eng.exact.as_mut() {
            cache.warm(self.ds, &eng.weights);
        }
    }

    /// Snapshot of the combined counters.
    pub fn stats(&self) -> ServerStats {
        let co = self.coalescer.stats();
        let mut st = ServerStats {
            queries: co.queries,
            flushes: co.flushes,
            max_flush: co.max_flush,
            shed: co.shed,
            timeouts: co.timeouts,
            flush_panics: co.flush_panics,
            ..ServerStats::default()
        };
        let eng = self.lock_engine();
        st.degraded_flushes = eng.degraded_flushes;
        if let Some(cache) = eng.exact.as_ref() {
            let cs = cache.stats();
            st.hits = cs.hits;
            st.misses = cs.misses;
            st.evictions = cs.evictions;
        }
        st
    }

    /// Zero every counter (e.g. after warm-up, before a benchmark run).
    pub fn reset_stats(&self) {
        self.coalescer.reset_stats();
        let mut eng = self.lock_engine();
        eng.degraded_flushes = 0;
        eng.pressure_streak = 0;
        if let Some(cache) = eng.exact.as_mut() {
            cache.reset_stats();
        }
    }

    /// Output width of the served model.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// The engine mode this server was built with.
    pub fn mode(&self) -> ServeMode {
        self.mode
    }

    /// The partition the server is keyed by.
    pub fn clusters(&self) -> &[Vec<u32>] {
        &self.clusters
    }

    /// node id → owning cluster id.
    pub fn owner(&self) -> &[u32] {
        &self.owner
    }

    /// Run one flush: every request list in, one response per list out
    /// (or one flush-level error the coalescer fans out to every
    /// rider).  Failpoints: `serve.flush` fails the flush typed,
    /// `serve.flush.delay` stalls it (drives queue pressure in chaos
    /// runs); both are untaken branches when inactive.
    fn execute(
        &self,
        lists: &[Vec<u32>],
    ) -> std::result::Result<Vec<Vec<f32>>, ServeError> {
        failpoint::check("serve.flush").map_err(|f| ServeError::Injected(f.site))?;
        failpoint::maybe_delay("serve.flush.delay", 5);
        let mut guard = self.lock_engine();
        let eng = &mut *guard;

        // degradation ladder: full-queue flushes are pressure; enough
        // of them in a row steps down to the halo-free engine, and the
        // first non-pressured flush steps back up
        let mut degraded_now = false;
        if self.degrade_after > 0 {
            if lists.len() >= self.queue_capacity {
                eng.pressure_streak += 1;
            } else {
                eng.pressure_streak = 0;
            }
            degraded_now = eng.pressure_streak >= self.degrade_after;
        }
        if degraded_now {
            if let Some(cl) = eng.degraded.as_mut() {
                eng.degraded_flushes += 1;
                return Ok(cl.execute(
                    self.ds,
                    &self.clusters,
                    &self.owner,
                    &eng.weights,
                    self.classes,
                    lists,
                ));
            }
        }
        if let Some(cache) = eng.exact.as_mut() {
            return Ok(lists
                .iter()
                .map(|l| cache.rows(self.ds, &eng.weights, l))
                .collect());
        }
        match eng.clustered.as_mut() {
            Some(cl) => Ok(cl.execute(
                self.ds,
                &self.clusters,
                &self.owner,
                &eng.weights,
                self.classes,
                lists,
            )),
            // unreachable by construction (one engine always exists),
            // but typed instead of panicking — a wedged server is the
            // one failure mode this layer must never have
            None => Err(ServeError::EnginePanicked),
        }
    }
}

/// Clustered-mode flush state: a reusable [`BatchAssembler`] plus the
/// double buffers [`pool::pipeline`] ping-pongs between assembly and
/// execution.
struct Clustered {
    residual: bool,
    threads: usize,
    /// include each cluster's one-hop neighbor ring in its subgraph
    /// (`false` = the degraded ladder's halo budget of zero: cheaper,
    /// coarser).
    halo: bool,
    /// cluster → subgraph footprint (|cluster ∪ neighbors| with halo,
    /// |cluster| without) — what packing groups clusters under.
    reach: Vec<usize>,
    b_max: usize,
    assembler: BatchAssembler,
    /// the two pipeline batches (taken during a flush, restored after).
    bufs: Option<(Batch, Batch)>,
    /// node → local row index in the batch last scattered; only
    /// positions of freshly written nodes are read, so it is never
    /// cleared ([`Batch::index_positions`]).
    pos: Vec<u32>,
    /// flush-wide `n × classes` staging rows (owned-cluster rows only).
    rows: Vec<f32>,
    /// cluster-level dedup scratch.
    marked: Vec<bool>,
    /// forward ping-pong buffers, grown on demand.
    cur: Vec<f32>,
    nxt: Vec<f32>,
}

impl Clustered {
    fn new(
        ds: &Dataset,
        clusters: &[Vec<u32>],
        norm: NormConfig,
        residual: bool,
        threads: usize,
        halo: bool,
    ) -> Clustered {
        let n = ds.n();
        let mut seen = vec![false; n];
        let mut touched: Vec<u32> = Vec::new();
        let mut reach = Vec::with_capacity(clusters.len());
        for nodes in clusters {
            let mut count = 0usize;
            for &v in nodes {
                if !seen[v as usize] {
                    seen[v as usize] = true;
                    touched.push(v);
                    count += 1;
                }
                if halo {
                    for &u in ds.graph.neighbors(v as usize) {
                        if !seen[u as usize] {
                            seen[u as usize] = true;
                            touched.push(u);
                            count += 1;
                        }
                    }
                }
            }
            for &v in &touched {
                seen[v as usize] = false;
            }
            touched.clear();
            reach.push(count);
        }
        let b_max = reach
            .iter()
            .copied()
            .max()
            .unwrap_or(1)
            .max(1)
            .next_multiple_of(8);
        let assembler = BatchAssembler::new(n, b_max, norm);
        Clustered {
            residual,
            threads,
            halo,
            reach,
            b_max,
            assembler,
            bufs: None,
            pos: vec![0u32; n],
            rows: Vec::new(),
            marked: vec![false; clusters.len()],
            cur: Vec::new(),
            nxt: Vec::new(),
        }
    }

    fn execute(
        &mut self,
        ds: &Dataset,
        clusters: &[Vec<u32>],
        owner: &[u32],
        weights: &[Tensor],
        classes: usize,
        lists: &[Vec<u32>],
    ) -> Vec<Vec<f32>> {
        // 1. clusters this flush touches, sorted for determinism
        let mut needed: Vec<u32> = Vec::new();
        for l in lists {
            for &v in l {
                let c = owner[v as usize] as usize;
                if !self.marked[c] {
                    self.marked[c] = true;
                    needed.push(c as u32);
                }
            }
        }
        needed.sort_unstable();
        for &c in &needed {
            self.marked[c as usize] = false;
        }

        // 2. greedy pack clusters into flush groups under the subgraph
        //    footprint budget (b_max covers the largest single cluster
        //    by construction, so every cluster fits somewhere)
        let mut groups: Vec<Vec<u32>> = Vec::new();
        let mut size = 0usize;
        for &c in &needed {
            let r = self.reach[c as usize];
            if groups.is_empty() || size + r > self.b_max {
                groups.push(vec![c]);
                size = r;
            } else {
                groups.last_mut().unwrap().push(c);
                size += r;
            }
        }

        // 3. one node set per group: clusters ∪ halo, or bare clusters
        //    when the halo budget is zero (degraded mode)
        let halo = self.halo;
        let group_nodes: Vec<Vec<u32>> = groups
            .iter()
            .map(|g| {
                let mut nodes: Vec<u32> = Vec::new();
                for &c in g {
                    for &v in &clusters[c as usize] {
                        nodes.push(v);
                        if halo {
                            nodes.extend_from_slice(ds.graph.neighbors(v as usize));
                        }
                    }
                }
                nodes.sort_unstable();
                nodes.dedup();
                nodes
            })
            .collect();

        // 4. double-buffered assemble/execute: group i+1 assembles on
        //    the pipeline's helper thread while group i runs the kernels
        if self.rows.len() < ds.n() * classes {
            self.rows.resize(ds.n() * classes, 0.0);
        }
        let (a, b) = match self.bufs.take() {
            Some(pair) => pair,
            None => (self.assembler.new_batch(ds), self.assembler.new_batch(ds)),
        };
        let assembler = &mut self.assembler;
        let pos = &mut self.pos;
        let rows = &mut self.rows;
        let cur = &mut self.cur;
        let nxt = &mut self.nxt;
        let (threads, residual) = (self.threads, self.residual);
        let bufs = pool::pipeline(
            group_nodes.len(),
            a,
            b,
            |i, batch| assembler.assemble_into(ds, &group_nodes[i], batch),
            |i, batch| {
                forward_scatter(
                    weights, batch, &groups[i], clusters, pos, rows, cur, nxt, threads,
                    residual, classes,
                );
                true
            },
        );
        self.bufs = Some(bufs);

        // 5. gather each request's rows from the staging buffer
        lists
            .iter()
            .map(|l| {
                let mut out = vec![0f32; l.len() * classes];
                for (i, &v) in l.iter().enumerate() {
                    out[i * classes..(i + 1) * classes].copy_from_slice(
                        &self.rows[v as usize * classes..(v as usize + 1) * classes],
                    );
                }
                out
            })
            .collect()
    }
}

/// Forward one assembled (clusters ∪ halo) batch through the tiled
/// kernels — mirroring the host backend's inference forward exactly —
/// then scatter **only the rows of clusters owned by this group** into
/// the flush staging buffer.  Halo rows are computed with truncated
/// neighborhoods and must never overwrite a row another group owns.
#[allow(clippy::too_many_arguments)]
fn forward_scatter(
    weights: &[Tensor],
    batch: &Batch,
    group: &[u32],
    clusters: &[Vec<u32>],
    pos: &mut [u32],
    out_rows: &mut [f32],
    cur: &mut Vec<f32>,
    nxt: &mut Vec<f32>,
    threads: usize,
    residual: bool,
    classes: usize,
) {
    let m = batch.n_real;
    if m == 0 {
        return;
    }
    let blk = &batch.block;
    debug_assert_eq!(blk.n(), m, "batch must carry its sparse block");
    let f_in = weights[0].dims[0];
    // chained with f_in the iterator is never empty, so no expect/panic
    // on the (construction-checked) nonempty-weights invariant
    let max_w = weights
        .iter()
        .map(|w| w.dims[1])
        .chain([f_in])
        .max()
        .unwrap_or(f_in);
    if cur.len() < m * max_w {
        cur.resize(m * max_w, 0.0);
    }
    if nxt.len() < m * max_w {
        nxt.resize(m * max_w, 0.0);
    }
    cur[..m * f_in].copy_from_slice(&batch.x.data[..m * f_in]);
    let mut f = f_in;
    let last = weights.len() - 1;
    for (l, w) in weights.iter().enumerate() {
        let g_dim = w.dims[1];
        spmm_layer_raw_into(
            &blk.offsets,
            &blk.cols,
            &blk.vals,
            &blk.self_loop,
            &cur[..m * f],
            f,
            w,
            l != last,
            threads,
            &mut nxt[..m * g_dim],
        );
        if residual && l != last && g_dim == f {
            for i in 0..m * f {
                nxt[i] += cur[i];
            }
        }
        std::mem::swap(cur, nxt);
        f = g_dim;
    }
    assert_eq!(f, classes, "final layer width must equal classes");
    batch.index_positions(pos);
    for &c in group {
        for &v in &clusters[c as usize] {
            let i = pos[v as usize] as usize;
            out_rows[v as usize * classes..(v as usize + 1) * classes]
                .copy_from_slice(&cur[i * classes..(i + 1) * classes]);
        }
    }
}
