//! Typed serving failures: every way a query can fail is an enum
//! variant callers can match on — overload shedding, deadline expiry,
//! bad input, a panicked flush, or an injected chaos fault.  Nothing in
//! the serving path panics across the request boundary, and `Clone`
//! lets one flush-level failure be distributed to every request that
//! rode in the flush.

/// Why a [`super::Server`] query (or coalescer submission) failed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control shed the request: the queue was full while a
    /// flush was in progress and the server is configured to shed
    /// rather than block ([`super::ServeConfig::shed_when_full`]).
    /// Retry later or at lower concurrency.
    Overloaded {
        /// queue depth observed at rejection time.
        queue_depth: usize,
    },
    /// The per-request deadline ([`super::ServeConfig::deadline_ms`])
    /// expired before the response arrived.  The request may still be
    /// executed by the in-flight flush; its response is discarded.
    DeadlineExceeded {
        /// the configured deadline that expired.
        deadline_ms: u64,
    },
    /// A queried node id is outside the served graph.
    NodeOutOfRange {
        /// the offending node id.
        node: u32,
        /// number of nodes in the served graph.
        n: usize,
    },
    /// The flush executing this request panicked (or broke the
    /// one-response-per-request contract); the engine recovered and
    /// subsequent requests proceed, but this one has no response.
    EnginePanicked,
    /// A failpoint fired in the serving path (chaos testing only);
    /// carries the site name.
    Injected(&'static str),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { queue_depth } => {
                write!(f, "server overloaded (queue depth {queue_depth}); request shed")
            }
            ServeError::DeadlineExceeded { deadline_ms } => {
                write!(f, "request deadline of {deadline_ms} ms exceeded")
            }
            ServeError::NodeOutOfRange { node, n } => {
                write!(f, "query node {node} out of range (n = {n})")
            }
            ServeError::EnginePanicked => {
                write!(f, "flush engine panicked; request has no response")
            }
            ServeError::Injected(site) => {
                write!(f, "injected fault at failpoint `{site}`")
            }
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_cause() {
        assert!(ServeError::Overloaded { queue_depth: 9 }.to_string().contains("9"));
        assert!(ServeError::DeadlineExceeded { deadline_ms: 25 }
            .to_string()
            .contains("25 ms"));
        assert!(ServeError::NodeOutOfRange { node: 7, n: 4 }.to_string().contains("7"));
        assert!(ServeError::Injected("serve.flush").to_string().contains("serve.flush"));
        // errors are cloneable so one flush failure fans out to every
        // coalesced request
        let e = ServeError::EnginePanicked;
        assert_eq!(e.clone(), e);
    }
}
