//! Partition-keyed layered activation cache — the exact-parity serving
//! engine.
//!
//! ## Keying
//!
//! One cache entry is a **(layer, cluster)** pair: the rows of layer
//! `l`'s activation matrix belonging to one partition's nodes, stored
//! inside a full-size `n × width_l` buffer per layer (so neighbor reads
//! across cluster boundaries are plain row indexing).  Entries are
//! computed over the **full-graph** normalized adjacency
//! ([`crate::norm::normalize_sparse`], cached in a
//! [`crate::norm::NormCache`]) through the row-subset kernel
//! [`spmm_layer_rows_into`] — every output row is the same pure per-row
//! function the offline
//! [`crate::coordinator::inference::full_forward_cached`] forward
//! computes, so served responses are **bit-identical** to rows of the
//! offline forward in every cache state (cold, warm, post-invalidation;
//! pinned by `tests/serve.rs`).
//!
//! ## Demand-driven fill
//!
//! A query walks need-sets top-down: the final layer needs the query
//! nodes' owner clusters; layer `l-1` additionally needs the
//! dependencies (owner clusters of all neighbors, plus the cluster
//! itself — self loops and residual reads) of every cluster *invalid*
//! at layer `l`.  Entries are then computed bottom-up, so intra-cluster
//! queries touch exactly `layers` entries and stay warm.
//!
//! ## Invalidation
//!
//! The cache carries a weight `version`; each entry records the version
//! it was computed at, and a mismatch is a miss (the overwrite of a
//! previously valid entry counts as an eviction).
//! [`ActivationCache::bump_version`] is called by
//! [`super::Server::install_weights`] — the `apply_grads` /
//! checkpoint-load integration point — so stale activations are never
//! served.  A weight *shape* change rebuilds the buffers outright.

use crate::coordinator::inference::spmm_layer_rows_into;
use crate::graph::Dataset;
use crate::norm::{NormCache, NormConfig};
use crate::runtime::Tensor;

/// Cache counters, one increment per (layer, cluster) entry touched.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// entries read while valid at the current weight version.
    pub hits: u64,
    /// entries computed (never computed before, or stale).
    pub misses: u64,
    /// valid-but-stale entries overwritten by a recompute (the
    /// weight-version invalidation path; capacity is fixed at
    /// layers × clusters preallocated buffers, so there are no
    /// capacity evictions).
    pub evictions: u64,
}

/// The partition-keyed activation cache; see the module docs.
pub struct ActivationCache {
    norm: NormConfig,
    residual: bool,
    threads: usize,
    /// cluster id → its node ids (a partition of `0..n`).
    clusters: Vec<Vec<u32>>,
    /// node id → owning cluster id.
    owner: Vec<u32>,
    /// cluster → clusters whose previous-layer rows its computation
    /// reads (owner clusters of all members' neighbors, plus itself),
    /// sorted ascending.
    deps: Vec<Vec<u32>>,
    /// per-layer output width (`weights[l].dims[1]`); rebuilt when the
    /// served weight shapes change.
    widths: Vec<usize>,
    /// per-layer `n × widths[l]` activation buffers.
    acts: Vec<Vec<f32>>,
    /// `entry_version[layer][cluster]`: weight version the entry was
    /// computed at (0 = never).
    entry_version: Vec<Vec<u64>>,
    /// current weight version (starts at 1 so 0 means "never").
    version: u64,
    stats: CacheStats,
    norm_cache: NormCache,
    /// per-cluster scratch mark for need-set dedup.
    mark: Vec<bool>,
    /// packed row scratch for one cluster's kernel output.
    row_scratch: Vec<f32>,
}

impl ActivationCache {
    /// Build a cache over a partition of `ds` (every node in exactly
    /// one cluster).  `norm`/`residual` must match how the served
    /// weights were trained; `threads` caps the kernel chunk count
    /// (chunk layout — and therefore bits — is thread-count
    /// independent).
    pub fn new(
        ds: &Dataset,
        clusters: Vec<Vec<u32>>,
        norm: NormConfig,
        residual: bool,
        threads: usize,
    ) -> ActivationCache {
        let n = ds.n();
        let k = clusters.len();
        assert!(k >= 1, "need at least one cluster");
        let covered: usize = clusters.iter().map(|c| c.len()).sum();
        assert_eq!(covered, n, "clusters must partition the node set");
        let mut owner = vec![u32::MAX; n];
        for (c, nodes) in clusters.iter().enumerate() {
            for &v in nodes {
                owner[v as usize] = c as u32;
            }
        }
        debug_assert!(owner.iter().all(|&o| o != u32::MAX));

        let mut mark = vec![false; k];
        let mut deps = Vec::with_capacity(k);
        for (c, nodes) in clusters.iter().enumerate() {
            let mut d = vec![c as u32];
            mark[c] = true;
            for &v in nodes {
                for &u in ds.graph.neighbors(v as usize) {
                    let o = owner[u as usize] as usize;
                    if !mark[o] {
                        mark[o] = true;
                        d.push(o as u32);
                    }
                }
            }
            d.sort_unstable();
            for &x in &d {
                mark[x as usize] = false;
            }
            deps.push(d);
        }

        ActivationCache {
            norm,
            residual,
            threads: threads.max(1),
            clusters,
            owner,
            deps,
            widths: Vec::new(),
            acts: Vec::new(),
            entry_version: Vec::new(),
            version: 1,
            stats: CacheStats::default(),
            norm_cache: NormCache::new(),
            mark,
            row_scratch: Vec::new(),
        }
    }

    /// The partition this cache is keyed by.
    pub fn clusters(&self) -> &[Vec<u32>] {
        &self.clusters
    }

    /// node id → owning cluster id.
    pub fn owner(&self) -> &[u32] {
        &self.owner
    }

    /// Invalidate every entry: subsequent queries recompute against the
    /// weights then passed to [`ActivationCache::rows_into`].  Called
    /// on every weight install (gradient step, checkpoint load).
    pub fn bump_version(&mut self) {
        self.version += 1;
    }

    /// Snapshot of the hit/miss/evict counters.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Zero the counters (e.g. after a warm-up pass).
    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Allocating wrapper over [`ActivationCache::rows_into`].
    pub fn rows(&mut self, ds: &Dataset, weights: &[Tensor], nodes: &[u32]) -> Vec<f32> {
        let classes = weights.last().expect("at least one layer").dims[1];
        let mut out = vec![0f32; nodes.len() * classes];
        self.rows_into(ds, weights, nodes, &mut out);
        out
    }

    /// Final-layer rows for `nodes` (`out.len() == nodes.len() *
    /// classes`), computing exactly the invalid (layer, cluster)
    /// entries the query depends on.  Bit-identical to gathering the
    /// same rows from
    /// [`crate::coordinator::inference::full_forward_cached`] under the
    /// same `(weights, norm, residual)`.
    pub fn rows_into(
        &mut self,
        ds: &Dataset,
        weights: &[Tensor],
        nodes: &[u32],
        out: &mut [f32],
    ) {
        assert!(!weights.is_empty(), "at least one layer");
        self.sync_shapes(ds, weights);
        let layers = weights.len();
        let classes = self.widths[layers - 1];
        assert_eq!(out.len(), nodes.len() * classes, "output buffer mismatch");

        // ---- need-set walk, top-down ------------------------------------
        // need[l] (1-based layer) = clusters whose layer-l rows the query
        // reads; sorted for a deterministic compute order.
        let mut need: Vec<Vec<u32>> = vec![Vec::new(); layers + 1];
        {
            let mark = &mut self.mark;
            for &v in nodes {
                let c = self.owner[v as usize] as usize;
                if !mark[c] {
                    mark[c] = true;
                    need[layers].push(c as u32);
                }
            }
            need[layers].sort_unstable();
            for &c in &need[layers] {
                mark[c as usize] = false;
            }
            for l in (2..=layers).rev() {
                let (lower, upper) = need.split_at_mut(l);
                let cur = &upper[0];
                let prev = &mut lower[l - 1];
                for &c in cur {
                    if self.entry_version[l - 1][c as usize] != self.version {
                        for &d in &self.deps[c as usize] {
                            if !mark[d as usize] {
                                mark[d as usize] = true;
                                prev.push(d);
                            }
                        }
                    }
                }
                prev.sort_unstable();
                for &d in prev.iter() {
                    mark[d as usize] = false;
                }
            }
        }

        // ---- ensure entries, bottom-up ----------------------------------
        let adj = self.norm_cache.get_or_compute(&ds.graph, self.norm);
        for l in 1..=layers {
            let li = l - 1;
            let w = &weights[li];
            let out_w = w.dims[1];
            let in_w = if l == 1 { ds.f_in } else { self.widths[li - 1] };
            let relu = l != layers;
            let res = self.residual && relu && out_w == in_w;
            let (lo, hi) = self.acts.split_at_mut(li);
            let x: &[f32] = if l == 1 { &ds.features } else { &lo[li - 1] };
            let y: &mut Vec<f32> = &mut hi[0];
            for &cu in &need[l] {
                let c = cu as usize;
                if self.entry_version[li][c] == self.version {
                    self.stats.hits += 1;
                    continue;
                }
                if self.entry_version[li][c] != 0 {
                    self.stats.evictions += 1;
                }
                self.stats.misses += 1;
                let rows = &self.clusters[c];
                let m = rows.len();
                if m == 0 {
                    self.entry_version[li][c] = self.version;
                    continue;
                }
                if self.row_scratch.len() < m * out_w {
                    self.row_scratch.resize(m * out_w, 0.0);
                }
                let scratch = &mut self.row_scratch[..m * out_w];
                spmm_layer_rows_into(
                    &ds.graph.offsets,
                    &ds.graph.cols,
                    &adj.vals,
                    &adj.self_loop,
                    x,
                    in_w,
                    w,
                    relu,
                    rows,
                    self.threads,
                    scratch,
                );
                // scatter into the layer buffer; residual mirrors
                // full_forward_cached (add the layer input, post-relu)
                for (i, &v) in rows.iter().enumerate() {
                    let dst = &mut y[v as usize * out_w..(v as usize + 1) * out_w];
                    dst.copy_from_slice(&scratch[i * out_w..(i + 1) * out_w]);
                    if res {
                        let src = &x[v as usize * in_w..(v as usize + 1) * in_w];
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d += s;
                        }
                    }
                }
                self.entry_version[li][c] = self.version;
            }
        }

        // ---- gather the response rows -----------------------------------
        let last = &self.acts[layers - 1];
        for (i, &v) in nodes.iter().enumerate() {
            out[i * classes..(i + 1) * classes]
                .copy_from_slice(&last[v as usize * classes..(v as usize + 1) * classes]);
        }
    }

    /// Precompute every (layer, cluster) entry at the current weight
    /// version — one full-graph layered forward through the cache.
    pub fn warm(&mut self, ds: &Dataset, weights: &[Tensor]) {
        let all: Vec<u32> = (0..ds.n() as u32).collect();
        let _ = self.rows(ds, weights, &all);
    }

    /// (Re)size the per-layer buffers when the served weight shapes
    /// change; a shape change discards every entry.
    fn sync_shapes(&mut self, ds: &Dataset, weights: &[Tensor]) {
        let widths: Vec<usize> = weights.iter().map(|w| w.dims[1]).collect();
        if widths == self.widths && self.acts.len() == widths.len() {
            return;
        }
        let n = ds.n();
        self.acts = widths.iter().map(|&w| vec![0f32; n * w]).collect();
        self.entry_version = vec![vec![0u64; self.clusters.len()]; widths.len()];
        self.widths = widths;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::inference::{full_forward_cached, gather_rows};
    use crate::coordinator::trainer::TrainState;
    use crate::graph::{Csr, Labels, Split, Task};
    use crate::runtime::ModelSpec;

    /// 8-node ring, 2 clusters of 4 interleaved so every cluster
    /// depends on the other.
    fn ring_ds() -> Dataset {
        let n = 8;
        let edges: Vec<(u32, u32)> =
            (0..n as u32).map(|v| (v, (v + 1) % n as u32)).collect();
        Dataset {
            name: "ring".into(),
            task: Task::Multiclass,
            graph: Csr::from_edges(n, &edges),
            f_in: 3,
            num_classes: 2,
            features: (0..n * 3).map(|i| (i as f32 * 0.37).sin()).collect(),
            labels: Labels::Multiclass(vec![0; n]),
            split: vec![Split::Train; n],
        }
    }

    #[test]
    fn cold_warm_and_invalidated_match_offline_forward_bitwise() {
        let ds = ring_ds();
        let spec = ModelSpec::gcn(ds.task, 2, ds.f_in, 5, ds.num_classes, 8);
        let mut weights = TrainState::init(&spec, 3).weights;
        let clusters = vec![vec![0, 2, 4, 6], vec![1, 3, 5, 7]];
        let mut cache =
            ActivationCache::new(&ds, clusters, NormConfig::PAPER_DEFAULT, false, 2);
        let mut nc = NormCache::new();
        let full =
            full_forward_cached(&ds, &weights, NormConfig::PAPER_DEFAULT, false, &mut nc);
        let q: Vec<u32> = vec![5, 0, 5];
        assert_eq!(cache.rows(&ds, &weights, &q), gather_rows(&full, 2, &q)); // cold
        let m1 = cache.stats().misses;
        assert!(m1 > 0);
        assert_eq!(cache.rows(&ds, &weights, &q), gather_rows(&full, 2, &q)); // warm
        assert_eq!(cache.stats().misses, m1, "warm query must not recompute");
        assert!(cache.stats().hits > 0);

        // invalidate: new weights must never see stale activations
        weights[0].data[1] += 0.5;
        cache.bump_version();
        let full2 =
            full_forward_cached(&ds, &weights, NormConfig::PAPER_DEFAULT, false, &mut nc);
        assert_eq!(cache.rows(&ds, &weights, &q), gather_rows(&full2, 2, &q));
        assert!(cache.stats().evictions > 0, "stale entries were overwritten");
    }

    #[test]
    fn residual_path_matches_offline_forward_bitwise() {
        let ds = ring_ds();
        // square 3→3→3→2 stack so the residual branch is exercised
        let spec = ModelSpec::gcn(ds.task, 3, ds.f_in, 3, ds.num_classes, 8)
            .with_residual();
        let weights = TrainState::init(&spec, 9).weights;
        let clusters = vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]];
        let mut cache = ActivationCache::new(&ds, clusters, NormConfig::ROW, true, 1);
        let mut nc = NormCache::new();
        let full = full_forward_cached(&ds, &weights, NormConfig::ROW, true, &mut nc);
        let q: Vec<u32> = (0..8).collect();
        assert_eq!(cache.rows(&ds, &weights, &q), gather_rows(&full, 2, &q));
    }
}
