//! Leader/follower request coalescing: concurrent callers enqueue
//! node-set queries into a bounded queue; the first caller to find no
//! active leader becomes one, drains the *whole* queue as a single
//! flush, executes it through a caller-supplied closure, distributes
//! the responses, and keeps draining while work is pending.  Everyone
//! else just blocks on a per-request response slot.
//!
//! This shape (instead of a dedicated worker thread) keeps the executor
//! a plain closure over the caller's borrows — no `'static` bounds, no
//! channel of boxed jobs — and makes single-threaded behavior exactly
//! one flush per query, which is what lets `tests/serve.rs` pin
//! byte-identical replays.

use std::sync::{Arc, Condvar, Mutex};

/// Coalescer counters (monotonic since construction or
/// [`Coalescer::reset_stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct CoalesceStats {
    /// queries submitted via [`Coalescer::run`].
    pub queries: u64,
    /// engine flushes executed; `flushes < queries` means coalescing
    /// actually merged concurrent requests.
    pub flushes: u64,
    /// largest number of requests merged into one flush.
    pub max_flush: usize,
}

/// One caller's response slot: filled by the flush leader, awaited by
/// the submitter.
struct Slot {
    done: Mutex<Option<Vec<f32>>>,
    cv: Condvar,
}

impl Slot {
    fn new() -> Slot {
        Slot { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn fill(&self, resp: Vec<f32>) {
        *self.done.lock().expect("slot poisoned") = Some(resp);
        self.cv.notify_one();
    }

    fn wait(&self) -> Vec<f32> {
        let mut g = self.done.lock().expect("slot poisoned");
        loop {
            if let Some(resp) = g.take() {
                return resp;
            }
            g = self.cv.wait(g).expect("slot poisoned");
        }
    }
}

struct Pending {
    nodes: Vec<u32>,
    slot: Arc<Slot>,
}

struct Queue {
    pending: Vec<Pending>,
    /// a leader is currently draining/executing.
    busy: bool,
    stats: CoalesceStats,
}

/// The request coalescer; see the module docs for the leader/follower
/// protocol.  Shared by reference across caller threads (`&Coalescer`
/// is all [`Coalescer::run`] needs).
pub struct Coalescer {
    q: Mutex<Queue>,
    /// signalled when the leader drains the queue (bounded-queue
    /// backpressure: submitters wait here while the queue is full *and*
    /// a leader is active).
    space: Condvar,
    capacity: usize,
}

impl Coalescer {
    /// A coalescer whose queue holds at most `capacity` (≥ 1) pending
    /// requests; submitters beyond that block until the active leader
    /// drains (when no leader is active the submitter becomes one, so
    /// the bound never deadlocks).
    pub fn new(capacity: usize) -> Coalescer {
        assert!(capacity >= 1, "coalescer capacity must be >= 1");
        Coalescer {
            q: Mutex::new(Queue {
                pending: Vec::new(),
                busy: false,
                stats: CoalesceStats::default(),
            }),
            space: Condvar::new(),
            capacity,
        }
    }

    /// Submit one query and block until its response arrives.
    ///
    /// `exec` runs each flush: it receives the node lists of every
    /// request merged into the flush (submission order) and must return
    /// exactly one response per list.  Only the flush leader's `exec`
    /// closure runs — a call whose request rides in another caller's
    /// flush never invokes its own — so `exec` must be the same logic
    /// for every caller (the [`super::Server`] passes its engine).
    ///
    /// Single-threaded use is deterministic by construction: the caller
    /// is always the leader, every query is its own flush, and the
    /// response is whatever `exec` returns for it.
    pub fn run<F>(&self, nodes: Vec<u32>, mut exec: F) -> Vec<f32>
    where
        F: FnMut(&[Vec<u32>]) -> Vec<Vec<f32>>,
    {
        let slot = Arc::new(Slot::new());
        let mut q = self.q.lock().expect("coalescer poisoned");
        while q.pending.len() >= self.capacity && q.busy {
            q = self.space.wait(q).expect("coalescer poisoned");
        }
        q.stats.queries += 1;
        q.pending.push(Pending { nodes, slot: Arc::clone(&slot) });
        if !q.busy {
            // become the leader: drain whole-queue flushes until no
            // work is pending, then hand leadership back
            q.busy = true;
            while !q.pending.is_empty() {
                let drained = std::mem::take(&mut q.pending);
                q.stats.flushes += 1;
                q.stats.max_flush = q.stats.max_flush.max(drained.len());
                drop(q);
                self.space.notify_all();
                let mut lists = Vec::with_capacity(drained.len());
                let mut slots = Vec::with_capacity(drained.len());
                for p in drained {
                    lists.push(p.nodes);
                    slots.push(p.slot);
                }
                let responses = exec(&lists);
                assert_eq!(
                    responses.len(),
                    lists.len(),
                    "flush executor must return one response per request"
                );
                for (s, resp) in slots.iter().zip(responses) {
                    s.fill(resp);
                }
                q = self.q.lock().expect("coalescer poisoned");
            }
            q.busy = false;
            drop(q);
            self.space.notify_all();
        } else {
            drop(q);
        }
        slot.wait()
    }

    /// Snapshot of the counters.
    pub fn stats(&self) -> CoalesceStats {
        self.q.lock().expect("coalescer poisoned").stats
    }

    /// Zero the counters (e.g. after a cache warm-up pass).
    pub fn reset_stats(&self) {
        self.q.lock().expect("coalescer poisoned").stats = CoalesceStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_thread_one_flush_per_query() {
        let co = Coalescer::new(4);
        for i in 0..5u32 {
            let resp = co.run(vec![i, i + 1], |lists| {
                assert_eq!(lists.len(), 1);
                lists.iter().map(|l| l.iter().map(|&v| v as f32).collect()).collect()
            });
            assert_eq!(resp, vec![i as f32, (i + 1) as f32]);
        }
        let st = co.stats();
        assert_eq!(st.queries, 5);
        assert_eq!(st.flushes, 5);
        assert_eq!(st.max_flush, 1);
        co.reset_stats();
        assert_eq!(co.stats().queries, 0);
    }

    #[test]
    fn empty_query_round_trips() {
        let co = Coalescer::new(1);
        let resp = co.run(Vec::new(), |lists| lists.iter().map(|_| Vec::new()).collect());
        assert!(resp.is_empty());
    }

    #[test]
    #[should_panic(expected = "one response per request")]
    fn executor_must_answer_every_request() {
        let co = Coalescer::new(2);
        let _ = co.run(vec![1], |_| Vec::new());
    }
}
