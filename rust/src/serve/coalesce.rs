//! Leader/follower request coalescing: concurrent callers enqueue
//! node-set queries into a bounded queue; the first caller to find no
//! active leader becomes one, drains the *whole* queue as a single
//! flush, executes it through a caller-supplied closure, distributes
//! the responses, and keeps draining while work is pending.  Everyone
//! else just blocks on a per-request response slot.
//!
//! This shape (instead of a dedicated worker thread) keeps the executor
//! a plain closure over the caller's borrows — no `'static` bounds, no
//! channel of boxed jobs — and makes single-threaded behavior exactly
//! one flush per query, which is what lets `tests/serve.rs` pin
//! byte-identical replays.
//!
//! ## Overload behavior (PR 8)
//!
//! Admission and completion are both bounded and typed:
//!
//! - **Shedding** ([`Coalescer::with_policy`] `shed_when_full`): a
//!   submitter finding the queue full while a flush is in progress gets
//!   [`ServeError::Overloaded`] immediately instead of blocking — queue
//!   wait stays bounded by `capacity × flush time` under any offered
//!   load.  When no leader is active the submitter always becomes one,
//!   so shedding never starves an idle server.
//! - **Deadlines** (`deadline_ms`): both the wait for queue space and
//!   the wait for the response observe a per-request deadline,
//!   returning [`ServeError::DeadlineExceeded`] on expiry.  A leader
//!   never deadlines its own flush — once it starts executing, it
//!   finishes and its own response is already in hand.
//! - **Panic isolation**: the flush executor runs under
//!   `catch_unwind`; a panic (or a broken one-response-per-request
//!   contract) fills every request in the flush with
//!   [`ServeError::EnginePanicked`], releases leadership, and lets the
//!   next submitter lead — one bad flush can no longer wedge the queue
//!   behind a permanently-set `busy` flag.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::error::ServeError;

/// Coalescer counters (monotonic since construction or
/// [`Coalescer::reset_stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct CoalesceStats {
    /// queries submitted via [`Coalescer::run`] (admitted ones; shed
    /// requests count only in `shed`).
    pub queries: u64,
    /// engine flushes executed; `flushes < queries` means coalescing
    /// actually merged concurrent requests.
    pub flushes: u64,
    /// largest number of requests merged into one flush.
    pub max_flush: usize,
    /// requests rejected at admission ([`ServeError::Overloaded`]).
    pub shed: u64,
    /// requests whose deadline expired ([`ServeError::DeadlineExceeded`]).
    pub timeouts: u64,
    /// flushes whose executor panicked (every rider got
    /// [`ServeError::EnginePanicked`]).
    pub flush_panics: u64,
}

/// One caller's response slot: filled by the flush leader, awaited by
/// the submitter.
struct Slot {
    done: Mutex<Option<Result<Vec<f32>, ServeError>>>,
    cv: Condvar,
}

impl Slot {
    fn new() -> Slot {
        Slot { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn fill(&self, resp: Result<Vec<f32>, ServeError>) {
        // a poisoned slot lock only means some waiter panicked; the
        // stored value is still a plain Option we fully overwrite
        *self.done.lock().unwrap_or_else(|p| p.into_inner()) = Some(resp);
        self.cv.notify_one();
    }

    /// Wait for the response; `None` when `deadline` expires first (the
    /// leader may still fill the slot later — the result is dropped
    /// with the Arc).
    fn wait_until(&self, deadline: Option<Instant>) -> Option<Result<Vec<f32>, ServeError>> {
        let mut g = self.done.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(resp) = g.take() {
                return Some(resp);
            }
            match deadline {
                None => g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner()),
                Some(dl) => {
                    let now = Instant::now();
                    if now >= dl {
                        return None;
                    }
                    let (ng, _) = self
                        .cv
                        .wait_timeout(g, dl - now)
                        .unwrap_or_else(|p| p.into_inner());
                    g = ng;
                }
            }
        }
    }
}

struct Pending {
    nodes: Vec<u32>,
    slot: Arc<Slot>,
}

struct Queue {
    pending: Vec<Pending>,
    /// a leader is currently draining/executing.
    busy: bool,
    stats: CoalesceStats,
}

/// The request coalescer; see the module docs for the leader/follower
/// protocol and the overload behavior.  Shared by reference across
/// caller threads (`&Coalescer` is all [`Coalescer::run`] needs).
pub struct Coalescer {
    q: Mutex<Queue>,
    /// signalled when the leader drains the queue (bounded-queue
    /// backpressure: submitters wait here while the queue is full *and*
    /// a leader is active).
    space: Condvar,
    capacity: usize,
    shed_when_full: bool,
    deadline_ms: u64,
}

impl Coalescer {
    /// A coalescer whose queue holds at most `capacity` (≥ 1) pending
    /// requests; submitters beyond that block until the active leader
    /// drains (when no leader is active the submitter becomes one, so
    /// the bound never deadlocks).  No shedding, no deadlines — the
    /// pre-PR-8 blocking behavior.
    pub fn new(capacity: usize) -> Coalescer {
        Coalescer::with_policy(capacity, false, 0)
    }

    /// A coalescer with overload policy: `shed_when_full` rejects
    /// at-capacity submissions with [`ServeError::Overloaded`] instead
    /// of blocking, and `deadline_ms` > 0 bounds each request's total
    /// wait (queue space + response) with
    /// [`ServeError::DeadlineExceeded`].
    pub fn with_policy(capacity: usize, shed_when_full: bool, deadline_ms: u64) -> Coalescer {
        assert!(capacity >= 1, "coalescer capacity must be >= 1");
        Coalescer {
            q: Mutex::new(Queue {
                pending: Vec::new(),
                busy: false,
                stats: CoalesceStats::default(),
            }),
            space: Condvar::new(),
            capacity,
            shed_when_full,
            deadline_ms,
        }
    }

    /// The queue mutex only ever guards plain bookkeeping (no
    /// invariants span a panic point while it is held), so a poisoned
    /// lock is recoverable by construction.
    fn lock_q(&self) -> MutexGuard<'_, Queue> {
        self.q.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Submit one query and block until its response arrives (or
    /// admission/deadline policy rejects it).
    ///
    /// `exec` runs each flush: it receives the node lists of every
    /// request merged into the flush (submission order) and must return
    /// exactly one response per list, or one flush-level error that is
    /// distributed to every rider.  Only the flush leader's `exec`
    /// closure runs — a call whose request rides in another caller's
    /// flush never invokes its own — so `exec` must be the same logic
    /// for every caller (the [`super::Server`] passes its engine).  A
    /// panicking `exec` is caught: every rider gets
    /// [`ServeError::EnginePanicked`] and the coalescer stays live.
    ///
    /// Single-threaded use is deterministic by construction: the caller
    /// is always the leader, every query is its own flush, and the
    /// response is whatever `exec` returns for it.
    pub fn run<F>(&self, nodes: Vec<u32>, mut exec: F) -> Result<Vec<f32>, ServeError>
    where
        F: FnMut(&[Vec<u32>]) -> Result<Vec<Vec<f32>>, ServeError>,
    {
        let deadline = if self.deadline_ms > 0 {
            Some(Instant::now() + Duration::from_millis(self.deadline_ms))
        } else {
            None
        };
        let slot = Arc::new(Slot::new());
        let mut q = self.lock_q();
        while q.pending.len() >= self.capacity && q.busy {
            if self.shed_when_full {
                q.stats.shed += 1;
                let queue_depth = q.pending.len();
                return Err(ServeError::Overloaded { queue_depth });
            }
            match deadline {
                None => q = self.space.wait(q).unwrap_or_else(|p| p.into_inner()),
                Some(dl) => {
                    let now = Instant::now();
                    if now >= dl {
                        q.stats.timeouts += 1;
                        return Err(ServeError::DeadlineExceeded {
                            deadline_ms: self.deadline_ms,
                        });
                    }
                    let (ng, _) = self
                        .space
                        .wait_timeout(q, dl - now)
                        .unwrap_or_else(|p| p.into_inner());
                    q = ng;
                }
            }
        }
        q.stats.queries += 1;
        q.pending.push(Pending { nodes, slot: Arc::clone(&slot) });
        if !q.busy {
            // become the leader: drain whole-queue flushes until no
            // work is pending, then hand leadership back
            q.busy = true;
            while !q.pending.is_empty() {
                let drained = std::mem::take(&mut q.pending);
                q.stats.flushes += 1;
                q.stats.max_flush = q.stats.max_flush.max(drained.len());
                drop(q);
                self.space.notify_all();
                let mut lists = Vec::with_capacity(drained.len());
                let mut slots = Vec::with_capacity(drained.len());
                for p in drained {
                    lists.push(p.nodes);
                    slots.push(p.slot);
                }
                // panic isolation: a panicking executor must not leave
                // `busy` set forever (the pre-PR-8 wedge) — catch it,
                // fail the riders typed, and continue draining
                let mut panicked = false;
                let outcome: Result<Vec<Vec<f32>>, ServeError> =
                    match catch_unwind(AssertUnwindSafe(|| {
                        let responses = exec(&lists)?;
                        assert_eq!(
                            responses.len(),
                            lists.len(),
                            "flush executor must return one response per request"
                        );
                        Ok(responses)
                    })) {
                        Ok(r) => r,
                        Err(_) => {
                            panicked = true;
                            Err(ServeError::EnginePanicked)
                        }
                    };
                match outcome {
                    Ok(responses) => {
                        for (s, resp) in slots.iter().zip(responses) {
                            s.fill(Ok(resp));
                        }
                    }
                    Err(e) => {
                        for s in &slots {
                            s.fill(Err(e.clone()));
                        }
                    }
                }
                q = self.lock_q();
                if panicked {
                    q.stats.flush_panics += 1;
                }
            }
            q.busy = false;
            drop(q);
            self.space.notify_all();
        } else {
            drop(q);
        }
        match slot.wait_until(deadline) {
            Some(resp) => resp,
            None => {
                self.lock_q().stats.timeouts += 1;
                Err(ServeError::DeadlineExceeded { deadline_ms: self.deadline_ms })
            }
        }
    }

    /// Current queue depth (requests admitted but not yet drained into
    /// a flush) — an ops signal, and what overload tests poll.
    pub fn pending(&self) -> usize {
        self.lock_q().pending.len()
    }

    /// Snapshot of the counters.
    pub fn stats(&self) -> CoalesceStats {
        self.lock_q().stats
    }

    /// Zero the counters (e.g. after a cache warm-up pass).
    pub fn reset_stats(&self) {
        self.lock_q().stats = CoalesceStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn echo(lists: &[Vec<u32>]) -> Result<Vec<Vec<f32>>, ServeError> {
        Ok(lists.iter().map(|l| l.iter().map(|&v| v as f32).collect()).collect())
    }

    #[test]
    fn single_thread_one_flush_per_query() {
        let co = Coalescer::new(4);
        for i in 0..5u32 {
            let resp = co
                .run(vec![i, i + 1], |lists| {
                    assert_eq!(lists.len(), 1);
                    echo(lists)
                })
                .unwrap();
            assert_eq!(resp, vec![i as f32, (i + 1) as f32]);
        }
        let st = co.stats();
        assert_eq!(st.queries, 5);
        assert_eq!(st.flushes, 5);
        assert_eq!(st.max_flush, 1);
        assert_eq!((st.shed, st.timeouts, st.flush_panics), (0, 0, 0));
        co.reset_stats();
        assert_eq!(co.stats().queries, 0);
    }

    #[test]
    fn empty_query_round_trips() {
        let co = Coalescer::new(1);
        let resp = co
            .run(Vec::new(), |lists| Ok(lists.iter().map(|_| Vec::new()).collect()))
            .unwrap();
        assert!(resp.is_empty());
    }

    #[test]
    fn executor_error_reaches_the_caller_typed() {
        let co = Coalescer::new(2);
        let r = co.run(vec![1], |_| Err(ServeError::Injected("serve.flush")));
        assert_eq!(r, Err(ServeError::Injected("serve.flush")));
        // the coalescer is still live
        assert_eq!(co.run(vec![2], |l| echo(l)).unwrap(), vec![2.0]);
        assert_eq!(co.stats().flush_panics, 0);
    }

    #[test]
    fn panicking_executor_fails_typed_and_does_not_wedge() {
        let co = Coalescer::new(2);
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // silence expected panic
        let r = co.run(vec![1], |_| -> Result<Vec<Vec<f32>>, ServeError> {
            panic!("engine blew up")
        });
        // a short-answering executor breaks the contract and is treated
        // like a panic too
        let short = co.run(vec![2], |_| Ok(Vec::new()));
        std::panic::set_hook(prev);
        assert_eq!(r, Err(ServeError::EnginePanicked));
        assert_eq!(short, Err(ServeError::EnginePanicked));
        let st = co.stats();
        assert_eq!(st.flush_panics, 2);
        // leadership was released: the next query executes normally
        assert_eq!(co.run(vec![3], |l| echo(l)).unwrap(), vec![3.0]);
    }

    /// Shedding: with the leader mid-flush and the queue at capacity,
    /// a further submission returns `Overloaded` immediately.
    #[test]
    fn full_queue_sheds_when_configured() {
        let co = Coalescer::with_policy(1, true, 0);
        let (enter_tx, enter_rx) = mpsc::channel::<()>();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        std::thread::scope(|s| {
            let leader = s.spawn(|| {
                // the handshake blocks only the first flush: the leader
                // drains the queued follower in a *second* flush, which
                // must run through unimpeded
                let mut first = true;
                co.run(vec![1], move |lists| {
                    if first {
                        first = false;
                        enter_tx.send(()).unwrap();
                        release_rx.recv().unwrap();
                    }
                    echo(lists)
                })
            });
            enter_rx.recv().unwrap(); // leader is inside exec, busy=true
            let follower = s.spawn(|| co.run(vec![2], |l| echo(l)));
            while co.pending() < 1 {
                std::thread::yield_now(); // follower admitted to the queue
            }
            // queue full + busy leader ⇒ typed shed, no blocking
            let shed = co.run(vec![3], |l| echo(l));
            assert_eq!(shed, Err(ServeError::Overloaded { queue_depth: 1 }));
            release_tx.send(()).unwrap();
            assert_eq!(leader.join().unwrap().unwrap(), vec![1.0]);
            // the queued follower was served by the leader's drain loop
            assert_eq!(follower.join().unwrap().unwrap(), vec![2.0]);
        });
        let st = co.stats();
        assert_eq!(st.shed, 1);
        assert_eq!(st.queries, 2, "shed requests are not admitted");
    }

    /// Deadlines: a follower whose response does not arrive in time
    /// gets `DeadlineExceeded`; the leader is unaffected.
    #[test]
    fn follower_deadline_expires_typed() {
        let co = Coalescer::with_policy(8, false, 30);
        let (enter_tx, enter_rx) = mpsc::channel::<()>();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        std::thread::scope(|s| {
            let leader = s.spawn(|| {
                let mut first = true;
                co.run(vec![1], move |lists| {
                    if first {
                        first = false;
                        enter_tx.send(()).unwrap();
                        release_rx.recv().unwrap();
                    }
                    echo(lists)
                })
            });
            enter_rx.recv().unwrap(); // leader stuck in exec
            // follower rides the queue and times out after ~30ms
            let timed_out = co.run(vec![2], |l| echo(l));
            assert_eq!(timed_out, Err(ServeError::DeadlineExceeded { deadline_ms: 30 }));
            release_tx.send(()).unwrap();
            // the leader's own request still completes (it never
            // deadlines its own flush)
            assert_eq!(leader.join().unwrap().unwrap(), vec![1.0]);
        });
        assert_eq!(co.stats().timeouts, 1);
    }
}
