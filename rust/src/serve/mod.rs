//! Online inference serving on the Session/Backend stack (ROADMAP
//! item 3): the cluster structure that makes Cluster-GCN training
//! batches dense and reusable is exactly a serving cache key, so this
//! layer answers single-node / node-batch queries with
//!
//! - [`cache::ActivationCache`] — a partition-keyed layered activation
//!   cache: per-(layer, cluster) entries over the full-graph-normalized
//!   adjacency, computed demand-driven through the tiled
//!   [`crate::coordinator::inference::spmm_layer_rows_into`] kernel and
//!   invalidated by weight version — responses are **bit-identical** to
//!   rows of the offline
//!   [`crate::coordinator::inference::full_forward_cached`] forward;
//! - [`coalesce::Coalescer`] — a leader/follower request coalescer:
//!   concurrent callers enqueue into a bounded queue, one caller drains
//!   the whole queue as a single flush, executes it, and distributes
//!   responses, so k concurrent queries cost one engine pass;
//! - [`server::Server`] — the synchronous in-process request/response
//!   front tying the two together (a socket transport is ROADMAP item
//!   4's job), with a weight-install hook (`apply_grads` /
//!   checkpoint-load integration point) that makes cache invalidation
//!   load-bearing;
//! - [`loadgen`] — a deterministic load generator
//!   ([`crate::util::Rng`] streams) replaying configurable query mixes
//!   (uniform, hot-set, intra- vs cross-cluster batches) and reporting
//!   p50/p99 latency + QPS.
//!
//! The CLI `serve` mode (see `cli/usage.txt`) loads a versioned
//! checkpoint, warms the cache, runs the load generator, and writes
//! `bench_results/BENCH_serve.json`.  See ARCHITECTURE.md "Serving
//! layer" for the cache keying / invalidation contract and PERF.md for
//! the expected hit-rate vs query-mix model.
//!
//! Overload safety (PR 8): every failure in the serving path is a
//! typed [`error::ServeError`] — the coalescer sheds at capacity and
//! enforces per-request deadlines, a panicked flush fails only its own
//! riders (poison-recovered engine lock, cache version bumped), and
//! under sustained full-queue pressure an exact server degrades to a
//! halo-free clustered engine.  See ARCHITECTURE.md "Robustness layer"
//! for the degradation ladder.
#![deny(missing_docs)]

pub mod cache;
pub mod coalesce;
pub mod error;
pub mod loadgen;
pub mod server;

pub use cache::{ActivationCache, CacheStats};
pub use coalesce::{CoalesceStats, Coalescer};
pub use error::ServeError;
pub use loadgen::{generate, run_load, LoadConfig, LoadReport, Mix};
pub use server::{ServeConfig, ServeMode, Server, ServerStats};
