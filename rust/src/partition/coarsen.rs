//! Graph contraction: collapse a matching into a coarse graph,
//! accumulating parallel-edge weights and node weights.

use crate::graph::Csr;

pub struct Coarse {
    pub graph: Csr,
    /// fine node -> coarse node.
    pub map: Vec<u32>,
}

pub fn contract(g: &Csr, mate: &[u32]) -> Coarse {
    let n = g.n();
    // assign coarse ids: the lower endpoint of each pair owns the id
    let mut map = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n {
        if map[v] != u32::MAX {
            continue;
        }
        let m = mate[v] as usize;
        map[v] = next;
        map[m] = next; // m == v for self-matched
        next += 1;
    }
    let nc = next as usize;

    // accumulate coarse node weights
    let mut node_weights = vec![0u32; nc];
    for v in 0..n {
        node_weights[map[v] as usize] += g.node_weights[v];
    }

    // build coarse adjacency: bucket per coarse node, dedupe with a
    // per-row marker array (O(nnz) total)
    let mut deg_cap = vec![0usize; nc];
    for v in 0..n {
        deg_cap[map[v] as usize] += g.degree(v);
    }
    let mut offsets = vec![0usize; nc + 1];
    for i in 0..nc {
        offsets[i + 1] = offsets[i] + deg_cap[i];
    }
    let mut cols = vec![0u32; offsets[nc]];
    let mut weights = vec![0u32; offsets[nc]];
    let mut fill = vec![0usize; nc];
    // marker: coarse col -> position in current row
    let mut pos_of = vec![usize::MAX; nc];
    let mut touched: Vec<u32> = Vec::new();

    // iterate coarse nodes by iterating their fine members
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); nc];
    for v in 0..n {
        members[map[v] as usize].push(v as u32);
    }
    for c in 0..nc {
        touched.clear();
        for &v in &members[c] {
            let v = v as usize;
            for (&u, &w) in g.neighbors(v).iter().zip(g.neighbor_weights(v)) {
                let cu = map[u as usize] as usize;
                if cu == c {
                    continue; // contracted internal edge disappears
                }
                if pos_of[cu] == usize::MAX {
                    let p = offsets[c] + fill[c];
                    fill[c] += 1;
                    cols[p] = cu as u32;
                    weights[p] = w;
                    pos_of[cu] = p;
                    touched.push(cu as u32);
                } else {
                    weights[pos_of[cu]] += w;
                }
            }
        }
        for &t in &touched {
            pos_of[t as usize] = usize::MAX;
        }
    }

    // compact rows (fill <= cap)
    let mut new_offsets = vec![0usize; nc + 1];
    for c in 0..nc {
        new_offsets[c + 1] = new_offsets[c] + fill[c];
    }
    let mut new_cols = vec![0u32; new_offsets[nc]];
    let mut new_weights = vec![0u32; new_offsets[nc]];
    for c in 0..nc {
        let src = offsets[c]..offsets[c] + fill[c];
        let dst = new_offsets[c]..new_offsets[c + 1];
        new_cols[dst.clone()].copy_from_slice(&cols[src.clone()]);
        new_weights[dst].copy_from_slice(&weights[src]);
    }
    // sort rows for Csr invariants
    for c in 0..nc {
        let r = new_offsets[c]..new_offsets[c + 1];
        let mut pairs: Vec<(u32, u32)> = new_cols[r.clone()]
            .iter()
            .zip(&new_weights[r.clone()])
            .map(|(&a, &b)| (a, b))
            .collect();
        pairs.sort_unstable();
        for (i, (cc, ww)) in pairs.into_iter().enumerate() {
            new_cols[new_offsets[c] + i] = cc;
            new_weights[new_offsets[c] + i] = ww;
        }
    }

    Coarse {
        graph: Csr {
            offsets: new_offsets,
            cols: new_cols,
            weights: new_weights,
            node_weights,
        },
        map,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::matching::heavy_edge_matching;
    use crate::util::Rng;

    #[test]
    fn contract_pair() {
        // square 0-1-2-3-0; match (0,1) and (2,3) manually
        let g = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let mate = vec![1, 0, 3, 2];
        let c = contract(&g, &mate);
        assert_eq!(c.graph.n(), 2);
        c.graph.validate().unwrap();
        // two parallel edges (1-2 and 3-0) merge into weight 2
        assert_eq!(c.graph.neighbor_weights(0), &[2]);
        assert_eq!(c.graph.node_weights, vec![2, 2]);
    }

    #[test]
    fn node_weight_conserved() {
        let g = Csr::from_edges(7, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6)]);
        let mut rng = Rng::new(2);
        let mate = heavy_edge_matching(&g, &mut rng);
        let c = contract(&g, &mate);
        assert_eq!(c.graph.total_node_weight(), 7);
        c.graph.validate().unwrap();
    }

    #[test]
    fn edge_weight_conserved_minus_internal() {
        let g = Csr::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let mut rng = Rng::new(3);
        let mate = heavy_edge_matching(&g, &mut rng);
        let c = contract(&g, &mate);
        let internal: u32 = (0..6)
            .map(|v| if mate[v] != v as u32 && g.has_edge(v, mate[v] as usize) { 1 } else { 0 })
            .sum::<u32>()
            / 2 * 2; // both directions
        let fine_total: u32 = g.weights.iter().sum();
        let coarse_total: u32 = c.graph.weights.iter().sum();
        assert_eq!(coarse_total, fine_total - internal);
    }

    #[test]
    fn map_is_consistent() {
        let g = Csr::from_edges(8, &[(0, 1), (2, 3), (4, 5), (6, 7), (1, 2), (5, 6)]);
        let mut rng = Rng::new(4);
        let mate = heavy_edge_matching(&g, &mut rng);
        let c = contract(&g, &mate);
        for v in 0..8 {
            assert_eq!(c.map[v], c.map[mate[v] as usize]);
            assert!((c.map[v] as usize) < c.graph.n());
        }
    }
}
