//! Heavy-edge matching — the coarsening step of the multilevel scheme
//! (Karypis & Kumar's METIS, which the paper uses as a black box; we
//! implement the algorithm family from scratch — DESIGN.md §2).
//!
//! Visits nodes in random order; an unmatched node matches its unmatched
//! neighbor with the heaviest connecting edge (ties → lower degree, to
//! keep coarse graphs sparse).  Unmatched leftovers match themselves.

use crate::graph::Csr;
use crate::util::Rng;

/// `mate[v] == v` means v is unmatched (self-matched).
pub fn heavy_edge_matching(g: &Csr, rng: &mut Rng) -> Vec<u32> {
    let n = g.n();
    let mut mate: Vec<u32> = vec![u32::MAX; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);

    for &v in &order {
        let v = v as usize;
        if mate[v] != u32::MAX {
            continue;
        }
        let mut best: Option<(u32, u32)> = None; // (weight, node)
        let nbrs = g.neighbors(v);
        let wts = g.neighbor_weights(v);
        for (&u, &w) in nbrs.iter().zip(wts) {
            if mate[u as usize] != u32::MAX {
                continue;
            }
            match best {
                None => best = Some((w, u)),
                Some((bw, bu)) => {
                    if w > bw || (w == bw && g.degree(u as usize) < g.degree(bu as usize)) {
                        best = Some((w, u));
                    }
                }
            }
        }
        match best {
            Some((_, u)) => {
                mate[v] = u;
                mate[u as usize] = v as u32;
            }
            None => mate[v] = v as u32,
        }
    }
    mate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matching_is_symmetric_and_total() {
        let g = Csr::from_edges(
            6,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0), (0, 3)],
        );
        let mut rng = Rng::new(1);
        let mate = heavy_edge_matching(&g, &mut rng);
        for v in 0..6 {
            let m = mate[v] as usize;
            assert!(m < 6);
            assert_eq!(mate[m] as usize, v, "asymmetric at {v}");
        }
    }

    fn set_weight(g: &mut Csr, u: usize, v: usize, w: u32) {
        for (a, b) in [(u, v), (v, u)] {
            let row = g.offsets[a]..g.offsets[a + 1];
            let idx = g.cols[row.clone()]
                .binary_search(&(b as u32))
                .expect("edge exists");
            g.weights[g.offsets[a] + idx] = w;
        }
    }

    #[test]
    fn prefers_heavy_edges() {
        // 1 - 0 = 2, 1 - 3: the heavy edge (0,2) must be matched no
        // matter the visit order (every other node has an alternative).
        let mut g = Csr::from_edges(4, &[(0, 1), (0, 2), (1, 3)]);
        set_weight(&mut g, 0, 2, 5);
        for seed in 0..16 {
            let mut rng = Rng::new(seed);
            let mate = heavy_edge_matching(&g, &mut rng);
            assert_eq!(mate[0], 2, "seed {seed}");
            assert_eq!(mate[2], 0, "seed {seed}");
        }
    }

    #[test]
    fn isolated_nodes_self_match() {
        let g = Csr::from_edges(3, &[(0, 1)]);
        let mut rng = Rng::new(5);
        let mate = heavy_edge_matching(&g, &mut rng);
        assert_eq!(mate[2], 2);
    }
}
