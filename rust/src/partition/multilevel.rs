//! The multilevel partitioner: coarsen (heavy-edge matching) until the
//! graph is small, partition the coarsest graph by region growing, then
//! uncoarsen with boundary refinement at every level — the METIS
//! algorithm family [Karypis & Kumar '98], which the paper uses for
//! cluster construction (Algorithm 1, line 1).

use crate::graph::Csr;
use crate::util::Rng;

use super::coarsen::contract;
use super::initial::region_growing;
use super::matching::heavy_edge_matching;
use super::refine::{refine, RefineParams};
use super::Partitioner;

#[derive(Clone, Debug)]
pub struct MultilevelParams {
    /// stop coarsening when n <= max(coarsest, k * per_part_floor).
    pub coarsest: usize,
    pub per_part_floor: usize,
    /// stop when a matching round shrinks the graph by < this factor
    /// (matching stalls on star-like graphs).
    pub min_shrink: f64,
    pub refine: RefineParams,
}

impl Default for MultilevelParams {
    fn default() -> Self {
        MultilevelParams {
            coarsest: 256,
            per_part_floor: 8,
            min_shrink: 0.95,
            refine: RefineParams::default(),
        }
    }
}

pub struct MultilevelPartitioner {
    pub params: MultilevelParams,
}

impl Default for MultilevelPartitioner {
    fn default() -> Self {
        MultilevelPartitioner { params: MultilevelParams::default() }
    }
}

impl Partitioner for MultilevelPartitioner {
    fn partition(&self, g: &Csr, k: usize, rng: &mut Rng) -> Vec<u32> {
        assert!(k >= 1);
        if k == 1 {
            return vec![0; g.n()];
        }
        let p = &self.params;
        let stop_at = p.coarsest.max(k * p.per_part_floor);

        // --- coarsening phase ------------------------------------------
        let mut levels: Vec<(Csr, Vec<u32>)> = Vec::new(); // (fine graph, fine->coarse map)
        let mut current = g.clone();
        while current.n() > stop_at {
            let mate = heavy_edge_matching(&current, rng);
            let coarse = contract(&current, &mate);
            let shrink = coarse.graph.n() as f64 / current.n() as f64;
            let stalled = shrink > p.min_shrink;
            levels.push((std::mem::replace(&mut current, coarse.graph), coarse.map));
            if stalled {
                break;
            }
        }

        // --- initial partition on the coarsest graph --------------------
        let mut part = region_growing(&current, k, rng);
        refine(&current, &mut part, k, &p.refine);

        // --- uncoarsening + refinement ----------------------------------
        while let Some((fine, map)) = levels.pop() {
            let mut fine_part = vec![0u32; fine.n()];
            for v in 0..fine.n() {
                fine_part[v] = part[map[v] as usize];
            }
            refine(&fine, &mut fine_part, k, &p.refine);
            part = fine_part;
        }
        part
    }

    fn name(&self) -> &'static str {
        "multilevel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, SbmSpec};
    use crate::partition::metrics::{balance, stats};
    use crate::partition::random::RandomPartitioner;

    fn sbm(n: usize, k: usize, seed: u64) -> (Csr, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let g = generate(
            &SbmSpec {
                n,
                communities: k,
                avg_deg: 12.0,
                intra_frac: 0.9,
                size_skew: 0.5,
            },
            &mut rng,
        );
        (g.graph, g.community)
    }

    #[test]
    fn beats_random_on_clustered_graph() {
        let (g, _) = sbm(3000, 30, 1);
        let mut rng = Rng::new(2);
        let ml = MultilevelPartitioner::default().partition(&g, 10, &mut rng);
        let rnd = RandomPartitioner.partition(&g, 10, &mut rng);
        let s_ml = stats(&g, &ml, 10);
        let s_rnd = stats(&g, &rnd, 10);
        // random keeps ~1/k of edges within parts; multilevel should keep
        // the vast majority (communities are recoverable)
        assert!(
            s_ml.within_fraction > 0.75,
            "multilevel within={:.3}",
            s_ml.within_fraction
        );
        assert!(
            s_ml.within_fraction > s_rnd.within_fraction + 0.3,
            "ml={:.3} rnd={:.3}",
            s_ml.within_fraction,
            s_rnd.within_fraction
        );
    }

    #[test]
    fn balanced() {
        let (g, _) = sbm(2000, 20, 3);
        let mut rng = Rng::new(4);
        let part = MultilevelPartitioner::default().partition(&g, 8, &mut rng);
        let b = balance(&g, &part, 8);
        assert!(b < 1.35, "imbalance {b}");
    }

    #[test]
    fn all_parts_nonempty() {
        let (g, _) = sbm(1500, 15, 5);
        let mut rng = Rng::new(6);
        let k = 12;
        let part = MultilevelPartitioner::default().partition(&g, k, &mut rng);
        let mut seen = vec![false; k];
        for &p in &part {
            seen[p as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "empty part");
    }

    #[test]
    fn k_one() {
        let (g, _) = sbm(500, 5, 7);
        let mut rng = Rng::new(8);
        let part = MultilevelPartitioner::default().partition(&g, 1, &mut rng);
        assert!(part.iter().all(|&p| p == 0));
    }

    #[test]
    fn many_parts() {
        // paper's regime: #parts comparable to #communities (Reddit: 1500)
        let (g, _) = sbm(4000, 40, 9);
        let mut rng = Rng::new(10);
        let k = 100;
        let part = MultilevelPartitioner::default().partition(&g, k, &mut rng);
        let s = stats(&g, &part, k);
        assert!(s.balance < 2.0, "imbalance {}", s.balance);
        assert!(s.within_fraction > 0.4, "within {}", s.within_fraction);
    }
}
