//! Single-level local-search partitioner: balanced random start + FM
//! boundary refinement until convergence (a Graclus-flavored
//! no-coarsening baseline — the paper cites both METIS [8] and
//! Graclus [4] as suitable cluster constructors).
//!
//! Used by the partitioner-ablation bench: it shows *why* the
//! multilevel scheme matters — pure local search gets stuck on large
//! graphs (local optima), giving a worse edge cut than
//! coarsen-partition-refine at the same balance.

use crate::graph::Csr;
use crate::util::Rng;

use super::random::RandomPartitioner;
use super::refine::{refine, RefineParams};
use super::Partitioner;

pub struct LocalSearchPartitioner {
    pub params: RefineParams,
    /// rounds of full refinement sweeps.
    pub rounds: usize,
}

impl Default for LocalSearchPartitioner {
    fn default() -> Self {
        LocalSearchPartitioner {
            params: RefineParams { epsilon: 0.10, max_passes: 10 },
            rounds: 3,
        }
    }
}

impl Partitioner for LocalSearchPartitioner {
    fn partition(&self, g: &Csr, k: usize, rng: &mut Rng) -> Vec<u32> {
        let mut part = RandomPartitioner.partition(g, k, rng);
        for _ in 0..self.rounds {
            let gain = refine(g, &mut part, k, &self.params);
            if gain <= 0 {
                break;
            }
        }
        part
    }

    fn name(&self) -> &'static str {
        "local-search"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, SbmSpec};
    use crate::partition::metrics::{balance, edge_cut};
    use crate::partition::MultilevelPartitioner;

    #[test]
    fn improves_over_random_but_loses_to_multilevel() {
        let mut rng = Rng::new(11);
        let sbm = generate(
            &SbmSpec {
                n: 4000,
                communities: 40,
                avg_deg: 12.0,
                intra_frac: 0.9,
                size_skew: 1.0,
            },
            &mut rng,
        );
        let g = &sbm.graph;
        let k = 10;
        let rd = RandomPartitioner.partition(g, k, &mut rng);
        let ls = LocalSearchPartitioner::default().partition(g, k, &mut rng);
        let ml = MultilevelPartitioner::default().partition(g, k, &mut rng);
        let (c_rd, c_ls, c_ml) =
            (edge_cut(g, &rd), edge_cut(g, &ls), edge_cut(g, &ml));
        assert!(c_ls < c_rd, "local search should beat random: {c_ls} vs {c_rd}");
        assert!(c_ml < c_ls, "multilevel should beat local search: {c_ml} vs {c_ls}");
    }

    #[test]
    fn stays_balanced() {
        let mut rng = Rng::new(12);
        let edges: Vec<(u32, u32)> = (0..999).map(|i| (i, i + 1)).collect();
        let g = Csr::from_edges(1000, &edges);
        let part = LocalSearchPartitioner::default().partition(&g, 8, &mut rng);
        assert!(balance(&g, &part, 8) < 1.25);
    }
}
