//! Boundary refinement (Fiduccia–Mattheyses flavored greedy): after
//! projecting a partition to a finer level, move boundary nodes to the
//! neighboring part with the best edge-cut gain, subject to a balance
//! constraint.  A few passes per level suffice (METIS does the same).

use crate::graph::Csr;

#[derive(Clone, Debug)]
pub struct RefineParams {
    /// allowed imbalance: max part weight <= (1 + epsilon) * average.
    pub epsilon: f64,
    pub max_passes: usize,
}

impl Default for RefineParams {
    fn default() -> Self {
        RefineParams { epsilon: 0.10, max_passes: 8 }
    }
}

/// In-place refinement; returns total gain (cut reduction in one-
/// directional edge weight; can be negative if balancing dominated).
pub fn refine(g: &Csr, part: &mut [u32], k: usize, params: &RefineParams) -> i64 {
    let n = g.n();
    let total_w = g.total_node_weight();
    let max_w = ((total_w as f64 / k as f64) * (1.0 + params.epsilon)).ceil() as u64;

    let mut weights = vec![0u64; k];
    for v in 0..n {
        weights[part[v] as usize] += g.node_weights[v] as u64;
    }

    // per-node connectivity to parts, computed lazily per visit
    let mut conn = vec![0u64; k];
    let mut touched: Vec<u32> = Vec::new();
    let mut total_gain = 0i64;

    for _pass in 0..params.max_passes {
        let mut pass_gain = 0i64;
        let mut moves = 0usize;
        for v in 0..n {
            let pv = part[v] as usize;
            if g.degree(v) == 0 {
                continue;
            }
            // connectivity of v to each adjacent part
            touched.clear();
            for (&u, &w) in g.neighbors(v).iter().zip(g.neighbor_weights(v)) {
                let pu = part[u as usize] as usize;
                if conn[pu] == 0 {
                    touched.push(pu as u32);
                }
                conn[pu] += w as u64;
            }
            let internal = conn[pv];
            let overweight = weights[pv] > max_w;
            // best external part: positive gain normally; when the
            // source part violates balance, accept the least-bad move
            // (FM-style balancing — greedy hill climbing alone can get
            // stuck on an infeasible partition).
            let mut best: Option<(i64, usize)> = None;
            for &t in &touched {
                let t = t as usize;
                if t == pv {
                    continue;
                }
                if weights[t] + g.node_weights[v] as u64 > max_w {
                    continue;
                }
                let gain = conn[t] as i64 - internal as i64;
                if (gain > 0 || overweight)
                    && best.map_or(true, |(bg, _)| gain > bg)
                {
                    best = Some((gain, t));
                }
            }
            if best.is_none() && overweight {
                // no adjacent part accepts: dump to the lightest part
                let (t, _) = weights
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &w)| w)
                    .unwrap();
                if t != pv && weights[t] + g.node_weights[v] as u64 <= max_w {
                    best = Some((-(internal as i64), t));
                }
            }
            if let Some((gain, t)) = best {
                weights[pv] -= g.node_weights[v] as u64;
                weights[t] += g.node_weights[v] as u64;
                part[v] = t as u32;
                pass_gain += gain;
                moves += 1;
            }
            for &t in &touched {
                conn[t as usize] = 0;
            }
        }
        total_gain += pass_gain;
        if moves == 0 {
            break;
        }
    }
    total_gain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::metrics::{balance, edge_cut};
    use crate::util::Rng;

    #[test]
    fn refine_improves_random_partition() {
        // two dense cliques joined by one edge; random partition cuts
        // through both, refinement should converge to the natural split.
        let mut edges = Vec::new();
        for i in 0..10u32 {
            for j in (i + 1)..10 {
                edges.push((i, j));
                edges.push((i + 10, j + 10));
            }
        }
        edges.push((0, 10));
        let g = Csr::from_edges(20, &edges);
        let mut rng = Rng::new(1);
        let mut part: Vec<u32> = (0..20).map(|_| rng.below(2) as u32).collect();
        let before = edge_cut(&g, &part);
        let gain = refine(&g, &mut part, 2, &RefineParams::default());
        let after = edge_cut(&g, &part);
        assert!(after < before, "no improvement: {before} -> {after}");
        assert_eq!(before as i64 - after as i64, gain * 2); // both dirs
        // optimal cut is the single bridge (2 directed entries)
        assert_eq!(after, 2, "did not find clique split");
    }

    #[test]
    fn respects_balance() {
        // path graph: refinement must not collapse everything into one part
        let edges: Vec<(u32, u32)> = (0..99).map(|i| (i, i + 1)).collect();
        let g = Csr::from_edges(100, &edges);
        let mut part: Vec<u32> = (0..100).map(|i| (i % 4) as u32).collect();
        refine(&g, &mut part, 4, &RefineParams::default());
        let b = balance(&g, &part, 4);
        // max_w is ceil((1+eps)*avg), so allow one node of slack
        assert!(b <= 1.10 + 1.0 / 25.0 + 1e-9, "imbalance {b}");
    }

    #[test]
    fn zero_gain_on_perfect_partition() {
        let mut edges = Vec::new();
        for i in 0..5u32 {
            for j in (i + 1)..5 {
                edges.push((i, j));
                edges.push((i + 5, j + 5));
            }
        }
        let g = Csr::from_edges(10, &edges);
        let mut part: Vec<u32> = (0..10).map(|i| if i < 5 { 0 } else { 1 }).collect();
        let gain = refine(&g, &mut part, 2, &RefineParams::default());
        assert_eq!(gain, 0);
    }
}
