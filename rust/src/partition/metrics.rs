//! Partition quality metrics: edge cut (the Δ of eq. (4) — the links the
//! block-diagonal approximation drops), balance, and per-part stats.

use crate::graph::Csr;

/// Directed entries crossing parts (== nnz(Δ) in eq. (4)).
pub fn edge_cut(g: &Csr, part: &[u32]) -> usize {
    let mut cut = 0usize;
    for v in 0..g.n() {
        for &u in g.neighbors(v) {
            if part[v] != part[u as usize] {
                cut += 1;
            }
        }
    }
    cut
}

/// max part weight / average part weight (1.0 = perfect).
pub fn balance(g: &Csr, part: &[u32], k: usize) -> f64 {
    let mut w = vec![0u64; k];
    for v in 0..g.n() {
        w[part[v] as usize] += g.node_weights[v] as u64;
    }
    let avg = g.total_node_weight() as f64 / k as f64;
    w.iter().copied().max().unwrap_or(0) as f64 / avg
}

#[derive(Clone, Debug)]
pub struct PartitionStats {
    pub k: usize,
    /// fraction of directed entries kept inside parts (embedding
    /// utilization of §3.1, normalized).
    pub within_fraction: f64,
    pub edge_cut: usize,
    pub balance: f64,
    pub min_part: usize,
    pub max_part: usize,
}

pub fn stats(g: &Csr, part: &[u32], k: usize) -> PartitionStats {
    let cut = edge_cut(g, part);
    let mut sizes = vec![0usize; k];
    for &p in part {
        sizes[p as usize] += 1;
    }
    PartitionStats {
        k,
        within_fraction: 1.0 - cut as f64 / g.nnz().max(1) as f64,
        edge_cut: cut,
        balance: balance(g, part, k),
        min_part: sizes.iter().copied().min().unwrap_or(0),
        max_part: sizes.iter().copied().max().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cut_and_balance() {
        let g = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let part = vec![0, 0, 1, 1];
        assert_eq!(edge_cut(&g, &part), 2); // edge 1-2 both directions
        assert!((balance(&g, &part, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stats_within_fraction() {
        let g = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let s = stats(&g, &[0, 0, 1, 1], 2);
        assert!((s.within_fraction - (1.0 - 2.0 / 6.0)).abs() < 1e-12);
        assert_eq!(s.min_part, 2);
        assert_eq!(s.max_part, 2);
    }
}
