//! Initial partition of the coarsest graph: greedy BFS region growing
//! (Karypis-Kumar style GGGP simplified): grow each part from a random
//! seed until its node-weight target is met, preferring frontier nodes
//! with the strongest connection to the growing region.

use crate::graph::Csr;
use crate::util::Rng;

pub fn region_growing(g: &Csr, k: usize, rng: &mut Rng) -> Vec<u32> {
    let n = g.n();
    assert!(k >= 1 && n >= k, "need n >= k (n={n}, k={k})");
    let total = g.total_node_weight();
    let target = total as f64 / k as f64;

    let mut part = vec![u32::MAX; n];
    let mut unassigned = n;

    for p in 0..k as u32 {
        if unassigned == 0 {
            break;
        }
        // budget for this part: keep remaining parts feasible
        let budget = target.ceil() as u64;
        // seed: random unassigned node
        let seed = {
            let mut s = rng.usize_below(n);
            while part[s] != u32::MAX {
                s = (s + 1) % n;
            }
            s
        };
        let mut weight = 0u64;
        // frontier with connection strength (simple Vec scan; coarse
        // graphs are small so O(frontier^2) is fine)
        let mut frontier: Vec<(u32, u32)> = vec![(seed as u32, 0)];
        while weight < budget && !frontier.is_empty() {
            // pick frontier node with max connectivity
            let (idx, _) = frontier
                .iter()
                .enumerate()
                .max_by_key(|(_, (_, w))| *w)
                .unwrap();
            let (v, _) = frontier.swap_remove(idx);
            let v = v as usize;
            if part[v] != u32::MAX {
                continue;
            }
            part[v] = p;
            weight += g.node_weights[v] as u64;
            unassigned -= 1;
            for (&u, &w) in g.neighbors(v).iter().zip(g.neighbor_weights(v)) {
                if part[u as usize] == u32::MAX {
                    if let Some(entry) =
                        frontier.iter_mut().find(|(fu, _)| *fu == u)
                    {
                        entry.1 += w;
                    } else {
                        frontier.push((u, w));
                    }
                }
            }
            // if region is stuck (disconnected), jump to a fresh seed
            if frontier.is_empty() && weight < budget && unassigned > 0 {
                let mut s = rng.usize_below(n);
                while part[s] != u32::MAX {
                    s = (s + 1) % n;
                }
                frontier.push((s as u32, 0));
            }
        }
    }

    // leftovers: attach to the lightest adjacent part (or lightest part)
    let mut weights = vec![0u64; k];
    for v in 0..n {
        if part[v] != u32::MAX {
            weights[part[v] as usize] += g.node_weights[v] as u64;
        }
    }
    for v in 0..n {
        if part[v] != u32::MAX {
            continue;
        }
        let mut best: Option<(u64, u32)> = None;
        for &u in g.neighbors(v) {
            let pu = part[u as usize];
            if pu != u32::MAX {
                let w = weights[pu as usize];
                if best.map_or(true, |(bw, _)| w < bw) {
                    best = Some((w, pu));
                }
            }
        }
        let p = best.map(|(_, p)| p).unwrap_or_else(|| {
            weights
                .iter()
                .enumerate()
                .min_by_key(|(_, &w)| w)
                .map(|(i, _)| i as u32)
                .unwrap()
        });
        part[v] = p;
        weights[p as usize] += g.node_weights[v] as u64;
    }
    part
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::metrics::{balance, edge_cut};

    fn grid(w: usize, h: usize) -> Csr {
        let mut edges = Vec::new();
        let id = |x: usize, y: usize| (y * w + x) as u32;
        for y in 0..h {
            for x in 0..w {
                if x + 1 < w {
                    edges.push((id(x, y), id(x + 1, y)));
                }
                if y + 1 < h {
                    edges.push((id(x, y), id(x, y + 1)));
                }
            }
        }
        Csr::from_edges(w * h, &edges)
    }

    #[test]
    fn covers_all_and_balanced() {
        let g = grid(16, 16);
        let mut rng = Rng::new(1);
        let part = region_growing(&g, 4, &mut rng);
        assert!(part.iter().all(|&p| p < 4));
        let b = balance(&g, &part, 4);
        assert!(b < 1.6, "imbalance {b}");
    }

    #[test]
    fn cut_beats_random_on_grid() {
        let g = grid(20, 20);
        let mut rng = Rng::new(2);
        let part = region_growing(&g, 4, &mut rng);
        let cut = edge_cut(&g, &part);
        // random 4-part cut on a 20x20 grid is ~ 3/4 of 760*2 entries;
        // region growing should do far better
        assert!(cut < 400, "cut too high: {cut}");
    }

    #[test]
    fn k_equals_one() {
        let g = grid(4, 4);
        let mut rng = Rng::new(3);
        let part = region_growing(&g, 1, &mut rng);
        assert!(part.iter().all(|&p| p == 0));
    }

    #[test]
    fn disconnected_graph_still_covered() {
        let g = Csr::from_edges(10, &[(0, 1), (2, 3), (4, 5), (6, 7), (8, 9)]);
        let mut rng = Rng::new(4);
        let part = region_growing(&g, 3, &mut rng);
        assert!(part.iter().all(|&p| p < 3));
    }
}
