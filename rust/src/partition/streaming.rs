//! Streaming multilevel partitioner for out-of-core graphs.
//!
//! The in-RAM `MultilevelPartitioner` clones the full graph per
//! coarsening level — fine for the miniatures, impossible at the
//! Amazon2M scale where the adjacency itself never fits. This module
//! partitions a [`GraphStorage`] (RAM or disk) with only the
//! *coarsened* graph resident:
//!
//! 1. **Pass A — streaming agglomeration.** Scan adjacency rows in
//!    ascending node order (chunk at a time via
//!    [`GraphStorage::scan_rows`]) and greedily merge each node into the
//!    already-formed group it shares the most edges with, subject to a
//!    size cap. One `u32` per node of state; no adjacency retained.
//! 2. **Pass B — coarse graph accumulation.** A second scan accumulates
//!    inter-group edge weights into per-group sorted maps, producing a
//!    weighted coarse [`Csr`] with `node_weights` = group sizes
//!    (~n / `group_cap` nodes).
//! 3. Run the existing in-RAM [`MultilevelPartitioner`] on the coarse
//!    graph and project the assignment back through the group map.
//!
//! Both passes are pure functions of the node order — chunk size cannot
//! change the result, which the tests pin. The RNG is consumed only by
//! the in-RAM stage, so a given seed yields one assignment regardless
//! of storage backend or chunking.

use std::collections::BTreeMap;

use crate::graph::{Csr, GraphStorage};
use crate::util::Rng;

use super::multilevel::{MultilevelParams, MultilevelPartitioner};
use super::Partitioner;

#[derive(Clone, Debug)]
pub struct StreamingParams {
    /// Max fine nodes per streaming group (pass A). Smaller caps keep
    /// more structure for the refinement stage; larger caps shrink the
    /// resident coarse graph. 8 matches one heavy-edge-matching level³.
    pub group_cap: usize,
    /// Rows per chunk for the two streaming scans (0 = one full chunk).
    pub chunk_rows: usize,
    /// Parameters for the in-RAM multilevel stage on the coarse graph.
    pub multilevel: MultilevelParams,
}

impl Default for StreamingParams {
    fn default() -> Self {
        StreamingParams {
            group_cap: 8,
            chunk_rows: crate::graph::store::DEFAULT_CHUNK_ROWS,
            multilevel: MultilevelParams::default(),
        }
    }
}

pub struct StreamingPartitioner {
    pub params: StreamingParams,
}

impl Default for StreamingPartitioner {
    fn default() -> Self {
        StreamingPartitioner { params: StreamingParams::default() }
    }
}

/// Result of the streaming agglomeration pass: a fine→group map and the
/// number of groups formed.
struct Grouping {
    group: Vec<u32>,
    num_groups: usize,
}

impl StreamingPartitioner {
    /// Partition a storage-backed graph into `k` parts. Same output
    /// contract as [`Partitioner::partition`]: `part[v] < k` for all v.
    pub fn partition_storage(
        &self,
        store: &GraphStorage,
        k: usize,
        rng: &mut Rng,
    ) -> Vec<u32> {
        assert!(k >= 1);
        let n = store.n();
        if k == 1 || n == 0 {
            return vec![0; n];
        }
        let grouping = self.agglomerate(store);
        let coarse = self.coarse_graph(store, &grouping);
        debug_assert_eq!(coarse.n(), grouping.num_groups);
        debug_assert_eq!(coarse.total_node_weight(), n as u64);

        // Degenerate: fewer groups than requested parts — every group
        // is its own part (group ids are dense 0..num_groups <= k).
        let coarse_part = if grouping.num_groups <= k {
            (0..grouping.num_groups as u32).collect()
        } else {
            let ml = MultilevelPartitioner { params: self.params.multilevel.clone() };
            ml.partition(&coarse, k, rng)
        };

        grouping
            .group
            .iter()
            .map(|&g| coarse_part[g as usize])
            .collect()
    }

    /// Pass A: ascending-order greedy agglomeration. Node `v` joins the
    /// group among its already-assigned neighbors with the highest
    /// connection count whose load is below `group_cap` (ties → lowest
    /// group id); with no eligible neighbor group it opens a new one.
    /// Depends only on node order, never on chunk boundaries.
    fn agglomerate(&self, store: &GraphStorage) -> Grouping {
        let n = store.n();
        let cap = self.params.group_cap.max(1) as u32;
        const UNASSIGNED: u32 = u32::MAX;
        let mut group = vec![UNASSIGNED; n];
        let mut load: Vec<u32> = Vec::new();
        // connection-count scratch, reset via the touched list
        let mut count: Vec<u32> = Vec::new();
        let mut touched: Vec<u32> = Vec::new();
        let mut num_groups = 0usize;

        store.scan_rows(self.params.chunk_rows, |v, row| {
            touched.clear();
            for &u in row {
                // ascending order: only u < v can be assigned already
                if (u as usize) >= v {
                    continue;
                }
                let g = group[u as usize];
                debug_assert_ne!(g, UNASSIGNED);
                if load[g as usize] >= cap {
                    continue;
                }
                if count[g as usize] == 0 {
                    touched.push(g);
                }
                count[g as usize] += 1;
            }
            let mut best: Option<u32> = None;
            for &g in &touched {
                best = Some(match best {
                    None => g,
                    Some(b) => {
                        let (cb, cg) = (count[b as usize], count[g as usize]);
                        if cg > cb || (cg == cb && g < b) {
                            g
                        } else {
                            b
                        }
                    }
                });
            }
            for &g in &touched {
                count[g as usize] = 0;
            }
            let g = match best {
                Some(g) => g,
                None => {
                    let g = num_groups as u32;
                    num_groups += 1;
                    load.push(0);
                    count.push(0);
                    g
                }
            };
            group[v] = g;
            load[g as usize] += 1;
        });
        Grouping { group, num_groups }
    }

    /// Pass B: accumulate the weighted coarse adjacency. Each fine
    /// directed entry (v, u) with `group[v] != group[u]` adds 1 to the
    /// coarse weight — the fine graph is symmetric, so the coarse graph
    /// is too. Memory is O(coarse nnz), not O(fine nnz).
    fn coarse_graph(&self, store: &GraphStorage, grouping: &Grouping) -> Csr {
        let nc = grouping.num_groups;
        let group = &grouping.group;
        let mut adj: Vec<BTreeMap<u32, u32>> = vec![BTreeMap::new(); nc];
        store.scan_rows(self.params.chunk_rows, |v, row| {
            let gv = group[v];
            for &u in row {
                let gu = group[u as usize];
                if gu != gv {
                    *adj[gv as usize].entry(gu).or_insert(0) += 1;
                }
            }
        });

        let mut offsets = vec![0usize; nc + 1];
        for g in 0..nc {
            offsets[g + 1] = offsets[g] + adj[g].len();
        }
        let nnz = offsets[nc];
        let mut cols = Vec::with_capacity(nnz);
        let mut weights = Vec::with_capacity(nnz);
        for m in &adj {
            for (&c, &w) in m {
                cols.push(c);
                weights.push(w);
            }
        }
        let mut node_weights = vec![0u32; nc];
        for &g in group {
            node_weights[g as usize] += 1;
        }
        Csr { offsets, cols, weights, node_weights }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, SbmSpec};
    use crate::graph::{Dataset, Labels, Split, Task};
    use crate::partition::metrics::stats;
    use crate::partition::random::RandomPartitioner;

    fn sbm_storage(n: usize, communities: usize, seed: u64) -> GraphStorage {
        let mut rng = Rng::new(seed);
        let g = generate(
            &SbmSpec {
                n,
                communities,
                avg_deg: 12.0,
                intra_frac: 0.9,
                size_skew: 0.5,
            },
            &mut rng,
        );
        let graph = g.graph;
        GraphStorage::InRam(Dataset {
            name: "sbm-test".into(),
            task: Task::Multiclass,
            graph,
            f_in: 1,
            num_classes: communities,
            features: vec![0.0; n],
            labels: Labels::Multiclass(g.community.clone()),
            split: vec![Split::Train; n],
        })
    }

    #[test]
    fn valid_assignment_and_deterministic() {
        let store = sbm_storage(1200, 12, 1);
        let k = 8;
        let p1 = StreamingPartitioner::default().partition_storage(&store, k, &mut Rng::new(5));
        let p2 = StreamingPartitioner::default().partition_storage(&store, k, &mut Rng::new(5));
        assert_eq!(p1.len(), 1200);
        assert!(p1.iter().all(|&p| (p as usize) < k));
        assert_eq!(p1, p2);
    }

    #[test]
    fn chunk_size_does_not_change_result() {
        let store = sbm_storage(900, 9, 2);
        let mut parts = Vec::new();
        for chunk_rows in [1usize, 7, 64, 0] {
            let sp = StreamingPartitioner {
                params: StreamingParams { chunk_rows, ..StreamingParams::default() },
            };
            parts.push(sp.partition_storage(&store, 6, &mut Rng::new(3)));
        }
        for p in &parts[1..] {
            assert_eq!(p, &parts[0]);
        }
    }

    #[test]
    fn beats_random_on_clustered_graph() {
        let store = sbm_storage(3000, 30, 4);
        let g = match &store {
            GraphStorage::InRam(ds) => ds.graph.clone(),
            _ => unreachable!(),
        };
        let k = 10;
        let sp = StreamingPartitioner::default()
            .partition_storage(&store, k, &mut Rng::new(6));
        let rnd = RandomPartitioner.partition(&g, k, &mut Rng::new(6));
        let s_sp = stats(&g, &sp, k);
        let s_rnd = stats(&g, &rnd, k);
        assert!(
            s_sp.within_fraction > 0.6,
            "streaming within={:.3}",
            s_sp.within_fraction
        );
        assert!(
            s_sp.within_fraction > s_rnd.within_fraction + 0.2,
            "sp={:.3} rnd={:.3}",
            s_sp.within_fraction,
            s_rnd.within_fraction
        );
    }

    #[test]
    fn group_cap_respected() {
        let store = sbm_storage(600, 6, 7);
        let sp = StreamingPartitioner::default();
        let grouping = sp.agglomerate(&store);
        let mut sizes = vec![0u32; grouping.num_groups];
        for &g in &grouping.group {
            assert_ne!(g, u32::MAX);
            sizes[g as usize] += 1;
        }
        assert!(sizes.iter().all(|&s| s <= sp.params.group_cap as u32));
        assert!(sizes.iter().all(|&s| s >= 1));
    }

    #[test]
    fn k_one_and_small_graphs() {
        let store = sbm_storage(40, 2, 8);
        let p = StreamingPartitioner::default().partition_storage(&store, 1, &mut Rng::new(9));
        assert!(p.iter().all(|&x| x == 0));
        // k larger than the group count: every group its own part
        let p = StreamingPartitioner::default().partition_storage(&store, 30, &mut Rng::new(9));
        assert!(p.iter().all(|&x| (x as usize) < 30));
    }
}
