//! Graph partitioning substrate (the paper delegates to METIS [8]; we
//! implement the multilevel family from scratch, plus the random
//! baseline of Table 2).

pub mod coarsen;
pub mod initial;
pub mod local_search;
pub mod matching;
pub mod metrics;
pub mod multilevel;
pub mod random;
pub mod refine;
pub mod streaming;

pub use metrics::{balance, edge_cut, PartitionStats};
pub use local_search::LocalSearchPartitioner;
pub use multilevel::{MultilevelParams, MultilevelPartitioner};
pub use random::RandomPartitioner;
pub use streaming::{StreamingParams, StreamingPartitioner};

use crate::graph::Csr;
use crate::util::Rng;

/// A partitioning algorithm: maps nodes to `k` parts.
pub trait Partitioner {
    fn partition(&self, g: &Csr, k: usize, rng: &mut Rng) -> Vec<u32>;
    fn name(&self) -> &'static str;
}

/// Group nodes by part id (the cluster node lists V_1..V_c of §3.1).
pub fn parts_to_clusters(part: &[u32], k: usize) -> Vec<Vec<u32>> {
    let mut clusters: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (v, &p) in part.iter().enumerate() {
        clusters[p as usize].push(v as u32);
    }
    clusters
}
