//! Random partition baseline (Table 2 of the paper: "partitions should
//! not be formed randomly").  Balanced by construction: a shuffled node
//! list is sliced into k equal chunks.

use crate::graph::Csr;
use crate::util::Rng;

use super::Partitioner;

pub struct RandomPartitioner;

impl Partitioner for RandomPartitioner {
    fn partition(&self, g: &Csr, k: usize, rng: &mut Rng) -> Vec<u32> {
        let n = g.n();
        let mut order: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut order);
        let mut part = vec![0u32; n];
        for (i, &v) in order.iter().enumerate() {
            part[v as usize] = (i * k / n) as u32;
        }
        part
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::metrics::balance;

    #[test]
    fn balanced_and_total() {
        let g = Csr::from_edges(100, &(0..99).map(|i| (i, i + 1)).collect::<Vec<_>>());
        let mut rng = Rng::new(1);
        let part = RandomPartitioner.partition(&g, 7, &mut rng);
        assert!(part.iter().all(|&p| p < 7));
        // 100 nodes over 7 parts: sizes 14/15, max/avg = 15/14.29
        assert!(balance(&g, &part, 7) < 1.06);
    }

    #[test]
    fn different_seeds_differ() {
        let g = Csr::from_edges(50, &(0..49).map(|i| (i, i + 1)).collect::<Vec<_>>());
        let p1 = RandomPartitioner.partition(&g, 5, &mut Rng::new(1));
        let p2 = RandomPartitioner.partition(&g, 5, &mut Rng::new(2));
        assert_ne!(p1, p2);
    }
}
