//! Minimal property-testing harness (crates.io proptest is unavailable
//! offline — DESIGN.md §7).
//!
//! `forall` runs a seeded generator through N cases with sizes ramping
//! up; on failure it re-runs the same seed at smaller sizes (shrink) and
//! panics with the smallest failing (seed, size) so failures reproduce
//! from the printed values alone.

use crate::util::Rng;

const SEED_BASE: u64 = 0xC6C4_5EED_0000_0001;

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// maximum "size" hint passed to generators (e.g. node count).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: SEED_BASE, max_size: 128 }
    }
}

impl Config {
    pub const fn with(cases: usize, seed: u64, max_size: usize) -> Config {
        Config { cases, seed, max_size }
    }
}

/// Run `prop(rng, size)`; `Err(msg)` fails the property.  On failure,
/// retries with smaller sizes to find a smaller counterexample, then
/// panics with the seed + size needed to reproduce.
pub fn forall<F>(cfg: &Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg
            .seed
            .wrapping_add(case as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // ramp size up over the run: early cases are small
        let size = 2 + (cfg.max_size.saturating_sub(2)) * (case + 1) / cfg.cases;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // shrink: try the same seed at smaller sizes
            let mut smallest = (size, msg.clone());
            let mut s = size / 2;
            while s >= 2 {
                let mut rng2 = Rng::new(seed);
                if let Err(m2) = prop(&mut rng2, s) {
                    smallest = (s, m2);
                }
                s /= 2;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 size {}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Generator helpers shared by property tests.
pub mod gen {
    use crate::graph::Csr;
    use crate::util::Rng;

    /// Random graph with ~`avg_deg` average degree.
    pub fn graph(rng: &mut Rng, n: usize, avg_deg: f64) -> Csr {
        let m = ((n as f64 * avg_deg) / 2.0) as usize;
        let mut edges = Vec::with_capacity(m);
        for _ in 0..m {
            let u = rng.below(n as u64) as u32;
            let v = rng.below(n as u64) as u32;
            edges.push((u, v));
        }
        Csr::from_edges(n, &edges)
    }

    /// Connected random graph (random tree + extra edges).
    pub fn connected_graph(rng: &mut Rng, n: usize, extra: usize) -> Csr {
        let mut edges = Vec::with_capacity(n + extra);
        for v in 1..n as u32 {
            let parent = rng.below(v as u64) as u32;
            edges.push((parent, v));
        }
        for _ in 0..extra {
            let u = rng.below(n as u64) as u32;
            let v = rng.below(n as u64) as u32;
            edges.push((u, v));
        }
        Csr::from_edges(n, &edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(&Config::with(16, 1, 64), "trivial", |rng, size| {
            let v = rng.usize_below(size.max(1));
            if v < size {
                Ok(())
            } else {
                Err(format!("{v} >= {size}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn forall_reports_failure() {
        forall(&Config::with(4, 2, 32), "always_fails", |_, _| {
            Err("nope".into())
        });
    }

    #[test]
    fn gen_graph_valid() {
        forall(&Config::with(16, 3, 96), "gen_graph_valid", |rng, size| {
            let g = gen::graph(rng, size, 4.0);
            g.validate()
        });
    }

    #[test]
    fn gen_connected_is_connected() {
        forall(&Config::with(12, 4, 64), "connected", |rng, size| {
            let g = gen::connected_graph(rng, size, 3);
            // BFS from 0 must reach all
            let mut seen = vec![false; g.n()];
            let mut queue = vec![0usize];
            seen[0] = true;
            while let Some(v) = queue.pop() {
                for &u in g.neighbors(v) {
                    if !seen[u as usize] {
                        seen[u as usize] = true;
                        queue.push(u as usize);
                    }
                }
            }
            if seen.iter().all(|&s| s) {
                Ok(())
            } else {
                Err("not connected".into())
            }
        });
    }
}
