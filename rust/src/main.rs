fn main() -> anyhow::Result<()> {
    cluster_gcn::cli::main()
}
