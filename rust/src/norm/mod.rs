//! Adjacency normalization + diagonal enhancement (paper §2, §3.3,
//! §6.2).
//!
//! Every variant the paper studies is a transform of the adjacency
//! *matrix*, so the AOT model needs no variants: rust builds the dense
//! normalized block per batch and feeds it through the one `A` input
//! (DESIGN.md §2).  Variants (Table 11):
//!
//! - `Sym`      — eq. (1)'s A' = D̃^{-1/2} (A+I) D̃^{-1/2} (Kipf-style).
//! - `RowNorm`  — eq. (10): Ã = (D+I)^{-1} (A+I).
//!
//! enhancements applied after normalization:
//!
//! - `AddIdentity`     — eq. (9): use Ã + I per layer.
//! - `AddLambdaDiag λ` — eq. (11): Ã + λ·diag(Ã).
//!
//! Renormalization happens **per batch** over the combined multi-cluster
//! subgraph (§6.2: "the new combined adjacency matrix should be
//! re-normalized"), which is why these run on local (batch) edges.

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NormKind {
    /// symmetric D̃^{-1/2}(A+I)D̃^{-1/2} — eq. (1) default.
    Sym,
    /// row (D+I)^{-1}(A+I) — eq. (10).
    RowNorm,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DiagEnhance {
    /// plain eq. (1)/(10).
    None,
    /// eq. (9): + I after normalization.
    AddIdentity,
    /// eq. (11): + λ diag(Ã).
    AddLambdaDiag(f32),
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NormConfig {
    pub kind: NormKind,
    pub enhance: DiagEnhance,
}

impl NormConfig {
    pub const PAPER_DEFAULT: NormConfig =
        NormConfig { kind: NormKind::Sym, enhance: DiagEnhance::None };

    /// Table 11 row "with (10)".
    pub const ROW: NormConfig =
        NormConfig { kind: NormKind::RowNorm, enhance: DiagEnhance::None };

    /// Table 11 row "with (10) + (9)".
    pub const ROW_IDENTITY: NormConfig =
        NormConfig { kind: NormKind::RowNorm, enhance: DiagEnhance::AddIdentity };

    /// Table 11 row "with (10) + (11), λ = 1".
    pub const ROW_LAMBDA1: NormConfig = NormConfig {
        kind: NormKind::RowNorm,
        enhance: DiagEnhance::AddLambdaDiag(1.0),
    };
}

/// Build the dense normalized (b_max, b_max) row-major block for a batch
/// of `n_local` nodes with the given induced directed `edges` (local
/// ids).  Self-loops (the +I of Ã) are added here.  Rows/cols >=
/// n_local stay zero (inert padding).  `out` must be b_max*b_max long;
/// it is fully overwritten.
///
/// Convenience wrapper over [`build_dense_block_prezeroed`] for one-off
/// callers; the L3 hot loop uses the prezeroed variant with a reused
/// `deg` scratch and dirty-row clearing (see `coordinator::batch`).
pub fn build_dense_block(
    n_local: usize,
    edges: &[(u32, u32)],
    b_max: usize,
    cfg: NormConfig,
    out: &mut [f32],
) {
    assert_eq!(out.len(), b_max * b_max);
    out.fill(0.0);
    let mut deg = Vec::with_capacity(n_local);
    build_dense_block_prezeroed(n_local, edges, b_max, cfg, &mut deg, out);
}

/// Normalized off-diagonal entry `Â[u,v]` from the per-node scales
/// (`su`/`sv` = 1/√d̃ for `Sym`, 1/d̃ for `RowNorm`).  Single source of
/// truth for both block realizations: the dense builder below and the
/// CSR `SparseBlock` the batch assembler carries compute every entry
/// through this helper, so the two views are **bit-identical** (the
/// host backend's parity contracts rely on it).
#[inline]
pub fn block_edge_val(cfg: NormConfig, su: f32, sv: f32) -> f32 {
    match cfg.kind {
        NormKind::Sym => su * sv,
        NormKind::RowNorm => su,
    }
}

/// Diagonal (self-loop) entry for node `i` with scale `si`, including
/// the diagonal enhancement.  See [`block_edge_val`] for the bitwise
/// dense/sparse contract.
#[inline]
pub fn block_diag_val(cfg: NormConfig, si: f32) -> f32 {
    let d = match cfg.kind {
        NormKind::Sym => si * si,
        NormKind::RowNorm => si,
    };
    match cfg.enhance {
        DiagEnhance::None => d,
        DiagEnhance::AddIdentity => d + 1.0,
        DiagEnhance::AddLambdaDiag(lambda) => d * (1.0 + lambda),
    }
}

/// Fold raw degrees (incl. self loop) into per-node normalization
/// scales in place: 1/√d̃ for `Sym`, 1/d̃ for `RowNorm`.  `deg` is
/// caller-owned scratch; the batch assembler reuses the folded scales
/// to value its sparse block without recomputing them.
pub fn fold_degree_scales(
    n_local: usize,
    edges: &[(u32, u32)],
    cfg: NormConfig,
    deg: &mut Vec<f32>,
) {
    deg.clear();
    deg.resize(n_local, 1.0);
    for &(u, _) in edges {
        deg[u as usize] += 1.0;
    }
    match cfg.kind {
        NormKind::Sym => deg.iter_mut().for_each(|d| *d = 1.0 / d.sqrt()),
        NormKind::RowNorm => deg.iter_mut().for_each(|d| *d = 1.0 / *d),
    }
}

/// Allocation-free core of [`build_dense_block`]: writes only the
/// normalized entries (edges + diagonal), assuming rows `0..n_local` of
/// `out` are already zero.  `deg` is caller-owned scratch reused across
/// calls; on return it holds the per-node normalization scale
/// (1/√d̃ for `Sym`, 1/d̃ for `RowNorm`), not the raw degree.
pub fn build_dense_block_prezeroed(
    n_local: usize,
    edges: &[(u32, u32)],
    b_max: usize,
    cfg: NormConfig,
    deg: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert!(n_local <= b_max);
    assert_eq!(out.len(), b_max * b_max);

    // degrees including self loop, folded in place into the
    // normalization scale (no second scratch vector)
    fold_degree_scales(n_local, edges, cfg, deg);

    for &(u, v) in edges {
        out[u as usize * b_max + v as usize] =
            block_edge_val(cfg, deg[u as usize], deg[v as usize]);
    }
    for i in 0..n_local {
        out[i * b_max + i] = block_diag_val(cfg, deg[i]);
    }
}

/// Process-wide count of [`normalize_sparse`] invocations.  The full
/// normalization is O(nnz) over the whole graph; the training pipeline
/// must hit it at most once per (dataset, `NormConfig`) — tests assert
/// on the delta of this counter around multi-eval runs.
static NORMALIZE_SPARSE_CALLS: std::sync::atomic::AtomicUsize =
    std::sync::atomic::AtomicUsize::new(0);

/// Total `normalize_sparse` calls so far in this process.
pub fn normalize_sparse_calls() -> usize {
    NORMALIZE_SPARSE_CALLS.load(std::sync::atomic::Ordering::Relaxed)
}

/// Normalized sparse adjacency values for the **full graph** (exact host
/// inference in `coordinator::inference`); returns per-entry values
/// aligned with `g.cols` plus the per-node self-loop value.
///
/// Hot-path callers should go through [`NormCache`] instead of calling
/// this directly — re-normalizing the full graph on every evaluation is
/// exactly the constant factor this cache removes.
pub fn normalize_sparse(
    g: &crate::graph::Csr,
    cfg: NormConfig,
) -> (Vec<f32>, Vec<f32>) {
    NORMALIZE_SPARSE_CALLS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let n = g.n();
    let deg: Vec<f32> = (0..n).map(|v| g.degree(v) as f32 + 1.0).collect();
    let mut vals = vec![0f32; g.nnz()];
    let mut self_loop = vec![0f32; n];
    match cfg.kind {
        NormKind::Sym => {
            let inv: Vec<f32> = deg.iter().map(|d| 1.0 / d.sqrt()).collect();
            for v in 0..n {
                for (i, &u) in g.neighbors(v).iter().enumerate() {
                    vals[g.offsets[v] + i] = inv[v] * inv[u as usize];
                }
                self_loop[v] = inv[v] * inv[v];
            }
        }
        NormKind::RowNorm => {
            for v in 0..n {
                let inv = 1.0 / deg[v];
                for i in 0..g.degree(v) {
                    vals[g.offsets[v] + i] = inv;
                }
                self_loop[v] = inv;
            }
        }
    }
    match cfg.enhance {
        DiagEnhance::None => {}
        DiagEnhance::AddIdentity => self_loop.iter_mut().for_each(|s| *s += 1.0),
        DiagEnhance::AddLambdaDiag(l) => {
            self_loop.iter_mut().for_each(|s| *s *= 1.0 + l)
        }
    }
    (vals, self_loop)
}

/// Storage-backed twin of [`normalize_sparse`]: full-graph normalized
/// values from a [`GraphStorage`](crate::graph::GraphStorage), reading
/// adjacency rows in `chunk_rows` chunks instead of requiring a resident
/// CSR (`RowNorm` needs no adjacency reads at all — degrees come from
/// the resident row-offset index).  Performs the exact same operations
/// in the exact same order, so the output is **bit-identical** to
/// `normalize_sparse` on the equivalent in-RAM graph (pinned by the
/// `store` test suite across chunk sizes).
///
/// Note the *output* is still O(nnz): this is the exact-inference /
/// serving normalization.  The out-of-core training path never calls it
/// — per-batch renormalization works on induced local edges only.
pub fn normalize_storage(
    store: &crate::graph::GraphStorage,
    cfg: NormConfig,
    chunk_rows: usize,
) -> (Vec<f32>, Vec<f32>) {
    NORMALIZE_SPARSE_CALLS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let n = store.n();
    let deg: Vec<f32> = (0..n).map(|v| store.degree(v) as f32 + 1.0).collect();
    let mut vals = vec![0f32; store.nnz()];
    let mut self_loop = vec![0f32; n];
    match cfg.kind {
        NormKind::Sym => {
            let inv: Vec<f32> = deg.iter().map(|d| 1.0 / d.sqrt()).collect();
            let mut pos = 0usize;
            store.scan_rows(chunk_rows, |v, row| {
                for &u in row {
                    vals[pos] = inv[v] * inv[u as usize];
                    pos += 1;
                }
                self_loop[v] = inv[v] * inv[v];
            });
            debug_assert_eq!(pos, vals.len());
        }
        NormKind::RowNorm => {
            for v in 0..n {
                let inv = 1.0 / deg[v];
                let (start, len) = (entry_offset(store, v), store.degree(v));
                vals[start..start + len].iter_mut().for_each(|x| *x = inv);
                self_loop[v] = inv;
            }
        }
    }
    match cfg.enhance {
        DiagEnhance::None => {}
        DiagEnhance::AddIdentity => self_loop.iter_mut().for_each(|s| *s += 1.0),
        DiagEnhance::AddLambdaDiag(l) => {
            self_loop.iter_mut().for_each(|s| *s *= 1.0 + l)
        }
    }
    (vals, self_loop)
}

/// Entry offset of node `v`'s adjacency row within the value array.
fn entry_offset(store: &crate::graph::GraphStorage, v: usize) -> usize {
    match store {
        crate::graph::GraphStorage::InRam(ds) => ds.graph.offsets[v],
        crate::graph::GraphStorage::OnDisk(dd) => dd.row_entry_offset(v) as usize,
    }
}

/// One cached [`normalize_sparse`] result: per-entry values aligned with
/// the graph's `cols` plus the per-node self-loop value.
#[derive(Clone, Debug)]
pub struct NormalizedAdj {
    pub cfg: NormConfig,
    pub vals: Vec<f32>,
    pub self_loop: Vec<f32>,
}

/// Per-dataset cache of full-graph normalizations, keyed by
/// [`NormConfig`].  Create one per training/eval run (the trainer and
/// every baseline own one) and route all full-graph normalization
/// through it: `normalize_sparse` then runs at most once per config.
///
/// Invalidation rule: a cache is bound to one immutable graph.  The
/// pipeline never mutates a `Dataset` in place, so entries never go
/// stale; if a caller ever rebuilds the graph it must drop the cache
/// with it.  Debug builds assert the entry still matches the graph's
/// (n, nnz) on every lookup.
#[derive(Default)]
pub struct NormCache {
    entries: Vec<NormalizedAdj>,
}

impl NormCache {
    pub fn new() -> NormCache {
        NormCache { entries: Vec::new() }
    }

    /// Index of the entry for `cfg`, computing it on first use.  The
    /// index stays valid for the cache's lifetime (entries are never
    /// evicted), so hot loops can hold it across mutable re-borrows.
    pub fn ensure(&mut self, g: &crate::graph::Csr, cfg: NormConfig) -> usize {
        if let Some(i) = self.entries.iter().position(|e| e.cfg == cfg) {
            debug_assert_eq!(
                self.entries[i].vals.len(),
                g.nnz(),
                "NormCache reused across different graphs"
            );
            debug_assert_eq!(self.entries[i].self_loop.len(), g.n());
            return i;
        }
        let (vals, self_loop) = normalize_sparse(g, cfg);
        self.entries.push(NormalizedAdj { cfg, vals, self_loop });
        self.entries.len() - 1
    }

    pub fn get(&self, idx: usize) -> &NormalizedAdj {
        &self.entries[idx]
    }

    pub fn get_or_compute(&mut self, g: &crate::graph::Csr, cfg: NormConfig) -> &NormalizedAdj {
        let i = self.ensure(g, cfg);
        &self.entries[i]
    }

    /// Number of normalizations actually computed (== distinct configs).
    pub fn computes(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    fn path3_edges() -> Vec<(u32, u32)> {
        // 0-1-2 both directions
        vec![(0, 1), (1, 0), (1, 2), (2, 1)]
    }

    #[test]
    fn rownorm_rows_sum_to_one() {
        let mut out = vec![0f32; 16];
        build_dense_block(3, &path3_edges(), 4, NormConfig::ROW, &mut out);
        for i in 0..3 {
            let s: f32 = out[i * 4..(i + 1) * 4].iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row {i} sums to {s}");
        }
        // padding row is zero
        assert!(out[12..16].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn sym_is_symmetric() {
        let mut out = vec![0f32; 16];
        build_dense_block(3, &path3_edges(), 4, NormConfig::PAPER_DEFAULT, &mut out);
        for i in 0..4 {
            for j in 0..4 {
                assert!((out[i * 4 + j] - out[j * 4 + i]).abs() < 1e-7);
            }
        }
        // known value: node 0 deg=2 (self+1), node 1 deg=3
        assert!((out[0] - 1.0 / 2.0).abs() < 1e-6); // 1/sqrt(2)^2
        assert!((out[1] - 1.0 / (2.0f32.sqrt() * 3.0f32.sqrt())).abs() < 1e-6);
    }

    #[test]
    fn add_identity() {
        let mut plain = vec![0f32; 16];
        let mut enh = vec![0f32; 16];
        build_dense_block(3, &path3_edges(), 4, NormConfig::ROW, &mut plain);
        build_dense_block(3, &path3_edges(), 4, NormConfig::ROW_IDENTITY, &mut enh);
        for i in 0..3 {
            assert!((enh[i * 4 + i] - plain[i * 4 + i] - 1.0).abs() < 1e-6);
        }
        // off-diagonal unchanged
        assert_eq!(plain[1], enh[1]);
    }

    #[test]
    fn lambda_diag_scales_diagonal() {
        let mut plain = vec![0f32; 16];
        let mut enh = vec![0f32; 16];
        build_dense_block(3, &path3_edges(), 4, NormConfig::ROW, &mut plain);
        build_dense_block(3, &path3_edges(), 4, NormConfig::ROW_LAMBDA1, &mut enh);
        for i in 0..3 {
            assert!((enh[i * 4 + i] - 2.0 * plain[i * 4 + i]).abs() < 1e-6);
        }
    }

    #[test]
    fn isolated_node_gets_self_loop_only() {
        let mut out = vec![0f32; 16];
        build_dense_block(3, &[], 4, NormConfig::ROW, &mut out);
        for i in 0..3 {
            assert!((out[i * 4 + i] - 1.0).abs() < 1e-6);
        }
    }

    /// Regression for the scratch-based builder: identical output to the
    /// allocating wrapper across every NormKind × DiagEnhance variant,
    /// with the deg scratch reused (dirty) between calls and the output
    /// pre-zeroed only on the rows the contract requires.
    #[test]
    fn prezeroed_matches_legacy_across_variants() {
        let edges = path3_edges();
        let b = 4;
        let configs = [
            NormConfig::PAPER_DEFAULT,
            NormConfig { kind: NormKind::Sym, enhance: DiagEnhance::AddIdentity },
            NormConfig { kind: NormKind::Sym, enhance: DiagEnhance::AddLambdaDiag(0.5) },
            NormConfig::ROW,
            NormConfig::ROW_IDENTITY,
            NormConfig::ROW_LAMBDA1,
        ];
        let mut deg = vec![9.0f32; 17]; // deliberately dirty, wrong-sized scratch
        for cfg in configs {
            let mut legacy = vec![0f32; b * b];
            build_dense_block(3, &edges, b, cfg, &mut legacy);

            let mut out = vec![f32::NAN; b * b];
            // contract: rows 0..n_local zeroed by the caller
            out[..3 * b].fill(0.0);
            build_dense_block_prezeroed(3, &edges, b, cfg, &mut deg, &mut out);
            for i in 0..3 * b {
                assert!(
                    (out[i] - legacy[i]).abs() < 1e-7,
                    "{cfg:?} differs at {i}: {} vs {}",
                    out[i],
                    legacy[i]
                );
            }
            // padding rows untouched by the prezeroed variant
            assert!(out[3 * b..].iter().all(|v| v.is_nan()));
        }
    }

    #[test]
    fn norm_cache_computes_once_per_config() {
        let g = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let mut cache = NormCache::new();
        let before = normalize_sparse_calls();
        for _ in 0..5 {
            let adj = cache.get_or_compute(&g, NormConfig::PAPER_DEFAULT);
            assert_eq!(adj.vals.len(), g.nnz());
        }
        for _ in 0..3 {
            cache.get_or_compute(&g, NormConfig::ROW);
        }
        assert_eq!(cache.computes(), 2);
        // the global counter moved by at least our two computes (other
        // tests may run normalize_sparse concurrently, so >= not ==)
        assert!(normalize_sparse_calls() - before >= 2);
        // cached entries match a fresh computation
        let (vals, sl) = normalize_sparse(&g, NormConfig::ROW);
        let idx = cache.ensure(&g, NormConfig::ROW);
        assert_eq!(cache.get(idx).vals, vals);
        assert_eq!(cache.get(idx).self_loop, sl);
    }

    #[test]
    fn sparse_matches_dense_on_full_graph() {
        let g = Csr::from_edges(3, &[(0, 1), (1, 2)]);
        let (vals, self_loop) = normalize_sparse(&g, NormConfig::ROW);
        let mut dense = vec![0f32; 9];
        let edges: Vec<(u32, u32)> = (0..3)
            .flat_map(|v| {
                g.neighbors(v).iter().map(move |&u| (v as u32, u)).collect::<Vec<_>>()
            })
            .collect();
        build_dense_block(3, &edges, 3, NormConfig::ROW, &mut dense);
        for v in 0..3 {
            assert!((dense[v * 3 + v] - self_loop[v]).abs() < 1e-7);
            for (i, &u) in g.neighbors(v).iter().enumerate() {
                assert!(
                    (dense[v * 3 + u as usize] - vals[g.offsets[v] + i]).abs() < 1e-7
                );
            }
        }
    }
}
