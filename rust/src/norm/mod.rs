//! Adjacency normalization + diagonal enhancement (paper §2, §3.3,
//! §6.2).
//!
//! Every variant the paper studies is a transform of the adjacency
//! *matrix*, so the AOT model needs no variants: rust builds the dense
//! normalized block per batch and feeds it through the one `A` input
//! (DESIGN.md §2).  Variants (Table 11):
//!
//! - `Sym`      — eq. (1)'s A' = D̃^{-1/2} (A+I) D̃^{-1/2} (Kipf-style).
//! - `RowNorm`  — eq. (10): Ã = (D+I)^{-1} (A+I).
//!
//! enhancements applied after normalization:
//!
//! - `AddIdentity`     — eq. (9): use Ã + I per layer.
//! - `AddLambdaDiag λ` — eq. (11): Ã + λ·diag(Ã).
//!
//! Renormalization happens **per batch** over the combined multi-cluster
//! subgraph (§6.2: "the new combined adjacency matrix should be
//! re-normalized"), which is why these run on local (batch) edges.

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NormKind {
    /// symmetric D̃^{-1/2}(A+I)D̃^{-1/2} — eq. (1) default.
    Sym,
    /// row (D+I)^{-1}(A+I) — eq. (10).
    RowNorm,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DiagEnhance {
    /// plain eq. (1)/(10).
    None,
    /// eq. (9): + I after normalization.
    AddIdentity,
    /// eq. (11): + λ diag(Ã).
    AddLambdaDiag(f32),
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NormConfig {
    pub kind: NormKind,
    pub enhance: DiagEnhance,
}

impl NormConfig {
    pub const PAPER_DEFAULT: NormConfig =
        NormConfig { kind: NormKind::Sym, enhance: DiagEnhance::None };

    /// Table 11 row "with (10)".
    pub const ROW: NormConfig =
        NormConfig { kind: NormKind::RowNorm, enhance: DiagEnhance::None };

    /// Table 11 row "with (10) + (9)".
    pub const ROW_IDENTITY: NormConfig =
        NormConfig { kind: NormKind::RowNorm, enhance: DiagEnhance::AddIdentity };

    /// Table 11 row "with (10) + (11), λ = 1".
    pub const ROW_LAMBDA1: NormConfig = NormConfig {
        kind: NormKind::RowNorm,
        enhance: DiagEnhance::AddLambdaDiag(1.0),
    };
}

/// Build the dense normalized (b_max, b_max) row-major block for a batch
/// of `n_local` nodes with the given induced directed `edges` (local
/// ids).  Self-loops (the +I of Ã) are added here.  Rows/cols >=
/// n_local stay zero (inert padding).  `out` must be b_max*b_max long;
/// it is fully overwritten.
pub fn build_dense_block(
    n_local: usize,
    edges: &[(u32, u32)],
    b_max: usize,
    cfg: NormConfig,
    out: &mut [f32],
) {
    assert!(n_local <= b_max);
    assert_eq!(out.len(), b_max * b_max);
    out.iter_mut().for_each(|x| *x = 0.0);

    // degrees including self loop
    let mut deg = vec![1.0f32; n_local];
    for &(u, _) in edges {
        deg[u as usize] += 1.0;
    }

    match cfg.kind {
        NormKind::Sym => {
            let inv_sqrt: Vec<f32> = deg.iter().map(|d| 1.0 / d.sqrt()).collect();
            for &(u, v) in edges {
                out[u as usize * b_max + v as usize] =
                    inv_sqrt[u as usize] * inv_sqrt[v as usize];
            }
            for i in 0..n_local {
                out[i * b_max + i] = inv_sqrt[i] * inv_sqrt[i];
            }
        }
        NormKind::RowNorm => {
            for &(u, v) in edges {
                out[u as usize * b_max + v as usize] = 1.0 / deg[u as usize];
            }
            for i in 0..n_local {
                out[i * b_max + i] = 1.0 / deg[i];
            }
        }
    }

    match cfg.enhance {
        DiagEnhance::None => {}
        DiagEnhance::AddIdentity => {
            for i in 0..n_local {
                out[i * b_max + i] += 1.0;
            }
        }
        DiagEnhance::AddLambdaDiag(lambda) => {
            for i in 0..n_local {
                out[i * b_max + i] *= 1.0 + lambda;
            }
        }
    }
}

/// Normalized sparse adjacency values for the **full graph** (exact host
/// inference in `coordinator::inference`); returns per-entry values
/// aligned with `g.cols` plus the per-node self-loop value.
pub fn normalize_sparse(
    g: &crate::graph::Csr,
    cfg: NormConfig,
) -> (Vec<f32>, Vec<f32>) {
    let n = g.n();
    let deg: Vec<f32> = (0..n).map(|v| g.degree(v) as f32 + 1.0).collect();
    let mut vals = vec![0f32; g.nnz()];
    let mut self_loop = vec![0f32; n];
    match cfg.kind {
        NormKind::Sym => {
            let inv: Vec<f32> = deg.iter().map(|d| 1.0 / d.sqrt()).collect();
            for v in 0..n {
                for (i, &u) in g.neighbors(v).iter().enumerate() {
                    vals[g.offsets[v] + i] = inv[v] * inv[u as usize];
                }
                self_loop[v] = inv[v] * inv[v];
            }
        }
        NormKind::RowNorm => {
            for v in 0..n {
                let inv = 1.0 / deg[v];
                for i in 0..g.degree(v) {
                    vals[g.offsets[v] + i] = inv;
                }
                self_loop[v] = inv;
            }
        }
    }
    match cfg.enhance {
        DiagEnhance::None => {}
        DiagEnhance::AddIdentity => self_loop.iter_mut().for_each(|s| *s += 1.0),
        DiagEnhance::AddLambdaDiag(l) => {
            self_loop.iter_mut().for_each(|s| *s *= 1.0 + l)
        }
    }
    (vals, self_loop)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    fn path3_edges() -> Vec<(u32, u32)> {
        // 0-1-2 both directions
        vec![(0, 1), (1, 0), (1, 2), (2, 1)]
    }

    #[test]
    fn rownorm_rows_sum_to_one() {
        let mut out = vec![0f32; 16];
        build_dense_block(3, &path3_edges(), 4, NormConfig::ROW, &mut out);
        for i in 0..3 {
            let s: f32 = out[i * 4..(i + 1) * 4].iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row {i} sums to {s}");
        }
        // padding row is zero
        assert!(out[12..16].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn sym_is_symmetric() {
        let mut out = vec![0f32; 16];
        build_dense_block(3, &path3_edges(), 4, NormConfig::PAPER_DEFAULT, &mut out);
        for i in 0..4 {
            for j in 0..4 {
                assert!((out[i * 4 + j] - out[j * 4 + i]).abs() < 1e-7);
            }
        }
        // known value: node 0 deg=2 (self+1), node 1 deg=3
        assert!((out[0] - 1.0 / 2.0).abs() < 1e-6); // 1/sqrt(2)^2
        assert!((out[1] - 1.0 / (2.0f32.sqrt() * 3.0f32.sqrt())).abs() < 1e-6);
    }

    #[test]
    fn add_identity() {
        let mut plain = vec![0f32; 16];
        let mut enh = vec![0f32; 16];
        build_dense_block(3, &path3_edges(), 4, NormConfig::ROW, &mut plain);
        build_dense_block(3, &path3_edges(), 4, NormConfig::ROW_IDENTITY, &mut enh);
        for i in 0..3 {
            assert!((enh[i * 4 + i] - plain[i * 4 + i] - 1.0).abs() < 1e-6);
        }
        // off-diagonal unchanged
        assert_eq!(plain[1], enh[1]);
    }

    #[test]
    fn lambda_diag_scales_diagonal() {
        let mut plain = vec![0f32; 16];
        let mut enh = vec![0f32; 16];
        build_dense_block(3, &path3_edges(), 4, NormConfig::ROW, &mut plain);
        build_dense_block(3, &path3_edges(), 4, NormConfig::ROW_LAMBDA1, &mut enh);
        for i in 0..3 {
            assert!((enh[i * 4 + i] - 2.0 * plain[i * 4 + i]).abs() < 1e-6);
        }
    }

    #[test]
    fn isolated_node_gets_self_loop_only() {
        let mut out = vec![0f32; 16];
        build_dense_block(3, &[], 4, NormConfig::ROW, &mut out);
        for i in 0..3 {
            assert!((out[i * 4 + i] - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn sparse_matches_dense_on_full_graph() {
        let g = Csr::from_edges(3, &[(0, 1), (1, 2)]);
        let (vals, self_loop) = normalize_sparse(&g, NormConfig::ROW);
        let mut dense = vec![0f32; 9];
        let edges: Vec<(u32, u32)> = (0..3)
            .flat_map(|v| {
                g.neighbors(v).iter().map(move |&u| (v as u32, u)).collect::<Vec<_>>()
            })
            .collect();
        build_dense_block(3, &edges, 3, NormConfig::ROW, &mut dense);
        for v in 0..3 {
            assert!((dense[v * 3 + v] - self_loop[v]).abs() < 1e-7);
            for (i, &u) in g.neighbors(v).iter().enumerate() {
                assert!(
                    (dense[v * 3 + u as usize] - vals[g.offsets[v] + i]).abs() < 1e-7
                );
            }
        }
    }
}
