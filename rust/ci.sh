#!/usr/bin/env bash
# CI gate: style + lints + docs + the tier-1 verify from ROADMAP.md.
# Run from anywhere inside the repo; requires the rust toolchain.
set -euo pipefail

cd "$(git -C "$(dirname "$0")" rev-parse --show-toplevel)"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, -D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo doc --no-deps (-D warnings; session/backend deny missing_docs) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "== cargo build --examples =="
cargo build --examples

echo "== backward parity (pool widths 1/2/8 inside each test) + FD gradients, release =="
cargo test --release -q backward
cargo test --release -q grads_match

echo "== shards parity gate (shards=1 bit-identical to HostBackend on a tiny SBM) =="
cargo test --release -q --test driver sharded
cargo test --release -q --test driver prefetch

echo "== backward bench smoke (release perf_probe on cora_like) =="
CGCN_ITERS=1 cargo run --release --example perf_probe -- cora_like 2 20

echo "CI gate passed."
