#!/usr/bin/env bash
# CI gate: style + lints + docs + the tier-1 verify from ROADMAP.md.
# Run from anywhere inside the repo; requires the rust toolchain.
#
# Two tiers:
#   fast (default)  — everything below except the CGCN_DEEP block; the
#                     SIMD additions are the forced-portable FD-gradient
#                     run and, on x86_64 with CGCN_SIMD unset, the
#                     "dispatch must not be silently portable" gate.
#   deep (CGCN_DEEP=1) — additionally re-runs the full test suite and
#                     the golden trajectories under CGCN_SIMD=portable
#                     (proves goldens are backend-independent), raises
#                     the simd_parity random-case count, and runs a
#                     larger-preset perf_probe.
set -euo pipefail

cd "$(git -C "$(dirname "$0")" rev-parse --show-toplevel)"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, -D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo doc --no-deps (-D warnings; session/backend deny missing_docs) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "== cargo build --examples =="
cargo build --examples

echo "== backward parity (pool widths 1/2/8 inside each test) + FD gradients, release =="
cargo test --release -q backward
cargo test --release -q grads_match

echo "== SIMD gates: forced-portable FD gradients + dispatch sanity =="
# the dispatched backend already ran above; this pins the portable
# fallback's numerics through the same finite-difference harness
CGCN_SIMD=portable cargo test --release -q grads_match
cargo test --release -q --test simd_parity
if [ "$(uname -m)" = "x86_64" ] && [ -z "${CGCN_SIMD:-}" ]; then
  # an AVX2-capable host must not silently dispatch to portable
  cargo test --release -q --test simd_parity -- --ignored
fi

echo "== shards parity gate (shards=1 bit-identical to HostBackend on a tiny SBM) =="
cargo test --release -q --test driver sharded
cargo test --release -q --test driver prefetch

echo "== VR-GCN resume-parity gate (interrupt -> checkpoint -> resume, bitwise) =="
cargo test --release -q --test driver vrgcn_resume
cargo test --release -q vrgcn_sparse

echo "== serve gates: cache parity + invalidation + coalescer concurrency =="
# exact-mode responses bit-identical to the offline forward (cold /
# warm / post-invalidation), stale entries never served after a weight
# install, concurrent callers coalesced without cross-talk
cargo test --release -q --test serve

echo "== golden-trace regression suite (bitwise loss/F1 trajectories, all methods) =="
GOLDEN="rust/tests/golden/trajectories.json"
[ -f "$GOLDEN" ] || GOLDEN="tests/golden/trajectories.json"
FRESH_GOLDEN=0
[ -f "$GOLDEN" ] || FRESH_GOLDEN=1
cargo test --release -q --test golden
if [ "$FRESH_GOLDEN" = 1 ]; then
  # first run recorded the goldens; re-run the match so the compare
  # path executes against the just-recorded file (non-vacuous gate),
  # and insist the file now exists so it can be committed
  cargo test --release -q --test golden trajectories_match
  [ -f rust/tests/golden/trajectories.json ] || [ -f tests/golden/trajectories.json ] || {
    echo "golden suite did not record trajectories.json" >&2; exit 1;
  }
  echo "NOTE: golden trajectories were recorded on this run — commit"
  echo "      rust/tests/golden/trajectories.json to pin future refactors."
fi

echo "== backward bench smoke (release perf_probe on cora_like) =="
CGCN_ITERS=1 cargo run --release --example perf_probe -- cora_like 2 20

if [ "${CGCN_DEEP:-0}" = 1 ]; then
  echo "== deep tier: full suite + goldens forced portable =="
  # golden trajectories (and everything else) must be bit-identical
  # under the portable fallback — the numeric contract that lets the
  # SIMD backends evolve without re-blessing traces
  CGCN_SIMD=portable cargo test --release -q
  CGCN_SIMD=portable cargo test --release -q --test golden

  echo "== deep tier: high-case-count SIMD parity sweep =="
  CGCN_DEEP=1 cargo test --release -q --test simd_parity

  echo "== deep tier: perf_probe on the larger preset =="
  CGCN_ITERS=3 cargo run --release --example perf_probe -- ppi_like 3 30

  echo "== deep tier: serve load-gen smoke + BENCH_serve.json well-formedness =="
  cargo run --release -- serve --preset cora_like --queries 300 --batch 4 \
    --mix hotset --clients 4 --seed 42
  test -f bench_results/BENCH_serve.json || {
    echo "serve did not write bench_results/BENCH_serve.json" >&2; exit 1;
  }
  # key presence; the p99 >= p50 > 0 invariant is asserted inside
  # cmd_serve before the file is written
  for key in p50_us p99_us mean_us qps hit_rate cache_hits cache_misses flushes; do
    grep -q "\"$key\"" bench_results/BENCH_serve.json || {
      echo "BENCH_serve.json missing key $key" >&2; exit 1;
    }
  done
fi

echo "CI gate passed."
