#!/usr/bin/env bash
# CI gate: style + lints + docs + the tier-1 verify from ROADMAP.md.
# Run from anywhere inside the repo; requires the rust toolchain.
#
# Two tiers:
#   fast (default)  — everything below except the CGCN_DEEP block; the
#                     SIMD additions are the forced-portable FD-gradient
#                     run and, on x86_64 with CGCN_SIMD unset, the
#                     "dispatch must not be silently portable" gate.
#   deep (CGCN_DEEP=1) — additionally re-runs the full test suite and
#                     the golden trajectories under CGCN_SIMD=portable
#                     (proves goldens are backend-independent), raises
#                     the simd_parity random-case count, runs a
#                     larger-preset perf_probe, the seeded end-to-end
#                     chaos sweep, the serve overload smoke, a
#                     scaled-down table8 out-of-core benchmark smoke,
#                     and the 2-worker distributed socket e2e with an
#                     injected torn frame.
set -euo pipefail

cd "$(git -C "$(dirname "$0")" rev-parse --show-toplevel)"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, -D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo doc --no-deps (-D warnings; session/backend deny missing_docs) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "== cargo build --examples =="
cargo build --examples

echo "== backward parity (pool widths 1/2/8 inside each test) + FD gradients, release =="
cargo test --release -q backward
cargo test --release -q grads_match

echo "== SIMD gates: forced-portable FD gradients + dispatch sanity =="
# the dispatched backend already ran above; this pins the portable
# fallback's numerics through the same finite-difference harness
CGCN_SIMD=portable cargo test --release -q grads_match
cargo test --release -q --test simd_parity
if [ "$(uname -m)" = "x86_64" ] && [ -z "${CGCN_SIMD:-}" ]; then
  # an AVX2-capable host must not silently dispatch to portable
  cargo test --release -q --test simd_parity -- --ignored
fi

echo "== shards parity gate (shards=1 bit-identical to HostBackend on a tiny SBM) =="
cargo test --release -q --test driver sharded
cargo test --release -q --test driver prefetch

echo "== distributed parity gates (workers=1 bitwise; torn-frame recovery bitwise) =="
# real spawned worker processes over UNIX/TCP sockets: workers=1 must
# replay the plain HostBackend run bit-identically, an injected torn
# request frame must recover to the fault-free 2-worker bits, and the
# CLI flag surface must match usage.txt (both directions)
cargo test --release -q --test distributed
cargo test --release -q usage_flags_match_command_whitelists

echo "== VR-GCN resume-parity gate (interrupt -> checkpoint -> resume, bitwise) =="
cargo test --release -q --test driver vrgcn_resume
cargo test --release -q vrgcn_sparse

echo "== serve gates: cache parity + invalidation + coalescer concurrency =="
# exact-mode responses bit-identical to the offline forward (cold /
# warm / post-invalidation), stale entries never served after a weight
# install, concurrent callers coalesced without cross-talk
cargo test --release -q --test serve

echo "== robustness gates: failpoint chaos suite (fast tier) =="
# torn / bit-flipped CGCNCKP3 files fail typed and fall back to the
# newest intact rotation slot; the session guard replays a fault-free
# run bitwise after an injected NaN and gives up typed when the budget
# is spent; the server sheds typed, degrades, and expires deadlines
# under injected flush stalls (the CGCN_DEEP sweep re-runs this
# end-to-end across seeds)
cargo test --release -q --test chaos

echo "== out-of-core storage gates: chunk parity + typed corruption + OOC replay =="
# the disk arm must be bit-identical to the resident arm at every seam
# (row reads, normalization, assembled batches, partitions, clustered
# eval, full training trajectories), and torn / bit-flipped stores must
# fail with typed StoreErrors instead of garbage reads
cargo test --release -q --test store

echo "== checkpoint-corruption gate (CLI: bit-flip + truncate, fallback load) =="
CKDIR="$(mktemp -d)"
STOREDIR="$(mktemp -d)"
trap 'rm -rf "$CKDIR" "$STOREDIR"' EXIT
cargo run --release -q -- train --preset cora_like --backend host --epochs 2 \
  --guard --keep 2 --lr-backoff 1.0 --save "$CKDIR/model.ckpt"
# flip bytes mid-file: the CRC trailer must reject the primary and the
# CLI must fall back to the newest intact .guard.e<N> rotation slot
printf 'CORRUPT!' | dd of="$CKDIR/model.ckpt" bs=1 seek=96 conv=notrunc status=none
cargo run --release -q -- train --preset cora_like --backend host --epochs 3 \
  --resume "$CKDIR/model.ckpt" 2> "$CKDIR/resume.log" || {
    cat "$CKDIR/resume.log" >&2
    echo "resume from a bit-flipped checkpoint must fall back, not die" >&2; exit 1;
  }
grep -q "falling back to" "$CKDIR/resume.log" || {
  cat "$CKDIR/resume.log" >&2
  echo "expected the corrupt-checkpoint fallback warning" >&2; exit 1;
}
# truncate it outright: same contract
head -c 40 "$CKDIR/model.ckpt" > "$CKDIR/t" && mv "$CKDIR/t" "$CKDIR/model.ckpt"
cargo run --release -q -- train --preset cora_like --backend host --epochs 3 \
  --resume "$CKDIR/model.ckpt" 2> "$CKDIR/trunc.log" || {
    cat "$CKDIR/trunc.log" >&2
    echo "resume from a truncated checkpoint must fall back, not die" >&2; exit 1;
  }
grep -q "falling back to" "$CKDIR/trunc.log" || {
  cat "$CKDIR/trunc.log" >&2
  echo "expected the truncated-checkpoint fallback warning" >&2; exit 1;
}

echo "== out-of-core e2e (CLI: datagen -> train -> eval -> serve, --storage disk) =="
# a deliberately tiny chunk size forces many pread windows per scan;
# every stage must run off the CGCNGS01 store and land on the same
# code paths the RAM arm exercises
cargo run --release -q -- datagen --preset cora_like --storage disk \
  --chunk-rows 3 --cache "$STOREDIR"
cargo run --release -q -- train --preset cora_like --backend host --epochs 2 \
  --storage disk --chunk-rows 3 --cache "$STOREDIR" --save "$STOREDIR/ooc.ckpt"
cargo run --release -q -- eval --preset cora_like --checkpoint "$STOREDIR/ooc.ckpt" \
  --storage disk --chunk-rows 3 --cache "$STOREDIR"
cargo run --release -q -- serve --preset cora_like --checkpoint "$STOREDIR/ooc.ckpt" \
  --queries 100 --batch 4 --clients 2 --seed 3 \
  --storage disk --chunk-rows 3 --cache "$STOREDIR" \
  --out "$STOREDIR/BENCH_serve_disk.json"
grep -q '"peak_rss_bytes"' "$STOREDIR/BENCH_serve_disk.json" || {
  echo "serve --storage disk did not record peak_rss_bytes" >&2; exit 1;
}

echo "== golden-trace regression suite (bitwise loss/F1 trajectories, all methods) =="
GOLDEN="rust/tests/golden/trajectories.json"
[ -f "$GOLDEN" ] || GOLDEN="tests/golden/trajectories.json"
FRESH_GOLDEN=0
[ -f "$GOLDEN" ] || FRESH_GOLDEN=1
cargo test --release -q --test golden
if [ "$FRESH_GOLDEN" = 1 ]; then
  # first run recorded the goldens; re-run the match so the compare
  # path executes against the just-recorded file (non-vacuous gate),
  # and insist the file now exists so it can be committed
  cargo test --release -q --test golden trajectories_match
  [ -f rust/tests/golden/trajectories.json ] || [ -f tests/golden/trajectories.json ] || {
    echo "golden suite did not record trajectories.json" >&2; exit 1;
  }
  echo "NOTE: golden trajectories were recorded on this run — commit"
  echo "      rust/tests/golden/trajectories.json to pin future refactors."
fi

echo "== backward bench smoke (release perf_probe on cora_like) =="
CGCN_ITERS=1 cargo run --release --example perf_probe -- cora_like 2 20

if [ "${CGCN_DEEP:-0}" = 1 ]; then
  echo "== deep tier: full suite + goldens forced portable =="
  # golden trajectories (and everything else) must be bit-identical
  # under the portable fallback — the numeric contract that lets the
  # SIMD backends evolve without re-blessing traces
  CGCN_SIMD=portable cargo test --release -q
  CGCN_SIMD=portable cargo test --release -q --test golden

  echo "== deep tier: high-case-count SIMD parity sweep =="
  CGCN_DEEP=1 cargo test --release -q --test simd_parity

  echo "== deep tier: perf_probe on the larger preset =="
  CGCN_ITERS=3 cargo run --release --example perf_probe -- ppi_like 3 30

  echo "== deep tier: serve load-gen smoke + BENCH_serve.json well-formedness =="
  cargo run --release -- serve --preset cora_like --queries 300 --batch 4 \
    --mix hotset --clients 4 --seed 42
  test -f bench_results/BENCH_serve.json || {
    echo "serve did not write bench_results/BENCH_serve.json" >&2; exit 1;
  }
  # key presence; the p99 >= p50 > 0 invariant is asserted inside
  # cmd_serve before the file is written
  for key in p50_us p99_us mean_us qps hit_rate cache_hits cache_misses flushes \
             ok shed timeouts errors flush_panics degraded_flushes; do
    grep -q "\"$key\"" bench_results/BENCH_serve.json || {
      echo "BENCH_serve.json missing key $key" >&2; exit 1;
    }
  done

  echo "== deep tier: seeded chaos sweep (train -> checkpoint -> resume -> serve) =="
  # per-seed fault schedules; every leg must recover to the golden bits
  # or fail typed — never panic, hang, or silently diverge
  CGCN_DEEP=1 cargo test --release -q --test chaos deep_seeded_chaos_sweep \
    -- --nocapture

  echo "== deep tier: serve overload smoke (shed + degradation counters) =="
  # a depth-2 shedding queue, 8 clients, and a 5 ms injected stall on
  # every flush: admission control and the degradation ladder must both
  # actually engage, and the counters must round-trip through the JSON
  cargo run --release -- serve --preset cora_like --queries 400 --batch 4 \
    --clients 8 --seed 7 --queue 2 --shed --degrade-after 1 \
    --failpoints 'serve.flush.delay=1' \
    --out bench_results/BENCH_serve_overload.json
  for key in ok shed timeouts errors flush_panics degraded_flushes; do
    grep -q "\"$key\"" bench_results/BENCH_serve_overload.json || {
      echo "BENCH_serve_overload.json missing key $key" >&2; exit 1;
    }
  done
  grep -Eq '"shed": *[1-9]' bench_results/BENCH_serve_overload.json || {
    echo "overload smoke shed nothing — admission control never engaged" >&2; exit 1;
  }
  grep -Eq '"degraded_flushes": *[1-9]' bench_results/BENCH_serve_overload.json || {
    echo "degradation ladder never engaged under sustained pressure" >&2; exit 1;
  }

  echo "== deep tier: table8 smoke (scaled-down OOC benchmark + RSS accounting) =="
  # the full amazon2m_full run is a release benchmark, not a CI gate; a
  # small preset proves the table8 pipeline end-to-end (streamed gen ->
  # streaming partition -> out-of-core train -> JSON report) and that
  # peak_rss_bytes is recorded and sane (> 0, under 32 GB)
  cargo run --release -- table8 --preset cora_like --parts 8 --q 2 --epochs 2 \
    --eval-every 1 --chunk-rows 64 --cache "$STOREDIR" \
    --out bench_results/BENCH_table8.json
  for key in peak_rss_bytes peak_batch_bytes epoch_secs partition_secs gen_secs \
             final_loss final_f1 n nnz parts q steps; do
    grep -q "\"$key\"" bench_results/BENCH_table8.json || {
      echo "BENCH_table8.json missing key $key" >&2; exit 1;
    }
  done
  RSS="$(grep -o '"peak_rss_bytes": *[0-9]*' bench_results/BENCH_table8.json \
    | grep -o '[0-9]*$')"
  if [ -z "$RSS" ] || [ "$RSS" -le 0 ] || [ "$RSS" -ge 34359738368 ]; then
    echo "peak_rss_bytes out of range: ${RSS:-missing}" >&2; exit 1;
  fi

  echo "== deep tier: 2-worker socket e2e (torn-frame fault -> recovery -> report) =="
  # two spawned worker processes over a UNIX socket, 8-bit quantized
  # gradient uplink, and one injected torn request frame: the run must
  # recover (exit 0), record the retry, and write the wire-cost report
  cargo run --release -- train --preset cora_like --backend host --epochs 2 \
    --workers 2 --transport unix --compress q8 \
    --failpoints 'dist.send.torn=1:1'
  test -f bench_results/BENCH_distributed.json || {
    echo "distributed train did not write bench_results/BENCH_distributed.json" >&2
    exit 1
  }
  for key in workers transport compress epochs dist_steps train_secs epoch_secs \
             bytes_tx bytes_rx grad_raw_bytes grad_wire_bytes compression_ratio \
             retries reconnects respawns final_loss peak_rss_bytes; do
    grep -q "\"$key\"" bench_results/BENCH_distributed.json || {
      echo "BENCH_distributed.json missing key $key" >&2; exit 1;
    }
  done
  grep -Eq '"retries": *[1-9]' bench_results/BENCH_distributed.json || {
    echo "torn-frame e2e recorded no retry — the fault never engaged" >&2; exit 1;
  }
fi

echo "CI gate passed."
