#!/usr/bin/env bash
# CI gate: style + lints + docs + the tier-1 verify from ROADMAP.md.
# Run from anywhere inside the repo; requires the rust toolchain.
set -euo pipefail

cd "$(git -C "$(dirname "$0")" rev-parse --show-toplevel)"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, -D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo doc --no-deps (-D warnings; session/backend deny missing_docs) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "== cargo build --examples =="
cargo build --examples

echo "== backward parity (pool widths 1/2/8 inside each test) + FD gradients, release =="
cargo test --release -q backward
cargo test --release -q grads_match

echo "== shards parity gate (shards=1 bit-identical to HostBackend on a tiny SBM) =="
cargo test --release -q --test driver sharded
cargo test --release -q --test driver prefetch

echo "== VR-GCN resume-parity gate (interrupt -> checkpoint -> resume, bitwise) =="
cargo test --release -q --test driver vrgcn_resume
cargo test --release -q vrgcn_sparse

echo "== golden-trace regression suite (bitwise loss/F1 trajectories, all methods) =="
GOLDEN="rust/tests/golden/trajectories.json"
[ -f "$GOLDEN" ] || GOLDEN="tests/golden/trajectories.json"
FRESH_GOLDEN=0
[ -f "$GOLDEN" ] || FRESH_GOLDEN=1
cargo test --release -q --test golden
if [ "$FRESH_GOLDEN" = 1 ]; then
  # first run recorded the goldens; re-run the match so the compare
  # path executes against the just-recorded file (non-vacuous gate),
  # and insist the file now exists so it can be committed
  cargo test --release -q --test golden trajectories_match
  [ -f rust/tests/golden/trajectories.json ] || [ -f tests/golden/trajectories.json ] || {
    echo "golden suite did not record trajectories.json" >&2; exit 1;
  }
  echo "NOTE: golden trajectories were recorded on this run — commit"
  echo "      rust/tests/golden/trajectories.json to pin future refactors."
fi

echo "== backward bench smoke (release perf_probe on cora_like) =="
CGCN_ITERS=1 cargo run --release --example perf_probe -- cora_like 2 20

echo "CI gate passed."
