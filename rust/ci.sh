#!/usr/bin/env bash
# CI gate: style + lints + docs + the tier-1 verify from ROADMAP.md.
# Run from anywhere inside the repo; requires the rust toolchain.
set -euo pipefail

cd "$(git -C "$(dirname "$0")" rev-parse --show-toplevel)"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, -D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo doc --no-deps (-D warnings; session/backend deny missing_docs) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "CI gate passed."
